//! Campaign runner: grids of experiments as one crash-safe unit of work.
//!
//! Reproducing FedEL's headline tables means sweeping strategy × seed ×
//! fleet × T_th grids against the baselines — dozens of runs per figure.
//! A [`CampaignCfg`] names such a grid; [`run_campaign`] expands it into
//! deterministic cells, fans the cells out across a bounded worker pool,
//! and writes every run through the shared, lockfile-guarded
//! [`RunStore`]. The campaign itself is as durable as its runs:
//!
//! * The cell → run-id assignment persists in
//!   `campaigns/<name>.json` ([`crate::store::schema::CampaignManifest`]),
//!   atomically rewritten under the store lock as workers claim cells.
//! * A killed campaign resumes by running it again (same name, same or no
//!   grid args): **complete cells are skipped**, cells with a checkpoint
//!   continue through the existing [`crate::fl::server::ResumeState`]
//!   machinery (bitwise-identical to never having stopped,
//!   `tests/campaign.rs`), and cells that died before their first
//!   checkpoint replay from round 0 into the same run.
//! * Two kill switches mirror `ServerCfg::halt_after` for drills and
//!   tests: `halt_after` kills each executing cell after k rounds, and
//!   `halt_after_cells` stops the campaign after n cells finish.
//!
//! Reporting rides the N-way [`crate::report::compare_runs`]:
//! [`report`] assembles the whole grid's time-to-accuracy table (and
//! `--json` form) from the stored manifests.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::config::{ExperimentCfg, FleetSpec};
use crate::fl::observer::NullObserver;
use crate::report::{compare_runs, CompareReport, Table};
use crate::sim::experiment::{resume_run, Experiment};
use crate::store::checkpoint::CheckpointObserver;
use crate::store::schema::{CampaignManifest, CellState, RunStatus, CAMPAIGN_SCHEMA_VERSION};
use crate::store::RunStore;
use crate::util::json::Json;
use crate::util::unix_now;

/// A grid of experiments over one base config. Every axis must be
/// non-empty; the cross product expands in a fixed order (strategies
/// outermost, then seeds, fleets, T_th factors), so cell indices and
/// labels are deterministic — which is what lets an interrupted campaign
/// find its cells again.
#[derive(Clone, Debug)]
pub struct CampaignCfg {
    pub name: String,
    /// Shared knobs (model, rounds, lr, ...); the grid axes override its
    /// strategy / seed / fleet / t_th_factor per cell.
    pub base: ExperimentCfg,
    pub strategies: Vec<String>,
    pub seeds: Vec<u64>,
    pub fleets: Vec<FleetSpec>,
    pub t_th_factors: Vec<f64>,
    /// Checkpoint cadence inside each cell (rounds).
    pub checkpoint_every: usize,
    /// Concurrent cells; 0 = one per host core. Purely a wall-clock knob:
    /// cells are independent deterministic experiments, so results are
    /// identical at any worker count.
    pub workers: usize,
    /// Kill switch, per cell: every cell *executed* by this invocation
    /// aborts after this many rounds (resumed cells run to completion —
    /// their config snapshot is authoritative). Not part of the spec
    /// snapshot.
    pub halt_after: Option<usize>,
    /// Kill switch, campaign-level: stop claiming cells once this many
    /// have been executed to completion by this invocation. Not part of
    /// the spec snapshot.
    pub halt_after_cells: Option<usize>,
    /// Per-cell progress lines on stderr.
    pub verbose: bool,
}

impl CampaignCfg {
    /// A 1×1×1×1 grid over the base config's own values; widen the axes
    /// from there.
    pub fn new(name: impl Into<String>, base: ExperimentCfg) -> CampaignCfg {
        CampaignCfg {
            name: name.into(),
            strategies: vec![base.strategy.clone()],
            seeds: vec![base.seed],
            fleets: vec![base.fleet.clone()],
            t_th_factors: vec![base.t_th_factor],
            base,
            checkpoint_every: 5,
            workers: 0,
            halt_after: None,
            halt_after_cells: None,
            verbose: false,
        }
    }

    /// The grid, expanded in deterministic order.
    pub fn cells(&self) -> anyhow::Result<Vec<CampaignCell>> {
        anyhow::ensure!(
            !self.strategies.is_empty()
                && !self.seeds.is_empty()
                && !self.fleets.is_empty()
                && !self.t_th_factors.is_empty(),
            "campaign {:?}: every grid axis needs at least one value",
            self.name
        );
        anyhow::ensure!(self.checkpoint_every >= 1, "checkpoint interval must be >= 1");
        let mut cells = Vec::new();
        for strategy in &self.strategies {
            for &seed in &self.seeds {
                for fleet in &self.fleets {
                    for &t_th in &self.t_th_factors {
                        cells.push(CampaignCell {
                            index: cells.len(),
                            strategy: strategy.clone(),
                            seed,
                            fleet: fleet.clone(),
                            t_th_factor: t_th,
                        });
                    }
                }
            }
        }
        Ok(cells)
    }

    /// The experiment a cell runs: the base config with the cell's axis
    /// values (plus this invocation's kill switch) applied.
    pub fn cell_cfg(&self, cell: &CampaignCell) -> ExperimentCfg {
        let mut cfg =
            self.base.with_axes(&cell.strategy, cell.seed, &cell.fleet, cell.t_th_factor);
        cfg.halt_after = self.halt_after;
        cfg.verbose = false;
        cfg.record_selections = false;
        cfg
    }

    /// Grid spec snapshot for the campaign manifest. Process knobs
    /// (workers, kill switches, verbosity) stay out, like
    /// `ExperimentCfg::to_json` keeps `halt_after` out of run snapshots.
    pub fn spec_to_json(&self) -> Json {
        Json::obj(vec![
            ("base", self.base.to_json()),
            (
                "strategies",
                Json::Arr(self.strategies.iter().map(|s| Json::Str(s.clone())).collect()),
            ),
            // u64 seeds ride strings, like everywhere else in the schema
            (
                "seeds",
                Json::Arr(self.seeds.iter().map(|s| Json::Str(format!("{s}"))).collect()),
            ),
            (
                "fleets",
                Json::Arr(self.fleets.iter().map(|f| Json::Str(f.label())).collect()),
            ),
            ("t_th_factors", Json::from_f64s(&self.t_th_factors)),
            ("checkpoint_every", Json::Num(self.checkpoint_every as f64)),
        ])
    }

    /// Rebuild a grid from a manifest's spec snapshot (the bare
    /// `campaign run --name <x>` resume path).
    pub fn from_spec_json(name: &str, j: &Json) -> anyhow::Result<CampaignCfg> {
        let strategies = j
            .arr("strategies")?
            .iter()
            .map(|s| {
                s.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow::anyhow!("spec strategy not a string"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let seeds = j
            .arr("seeds")?
            .iter()
            .map(|s| match s {
                Json::Str(s) => s.parse().map_err(|e| anyhow::anyhow!("spec seed {s:?}: {e}")),
                Json::Num(x) => Ok(*x as u64),
                other => anyhow::bail!("spec seed {other:?} not a number or string"),
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let fleets = j
            .arr("fleets")?
            .iter()
            .map(|s| {
                FleetSpec::parse(
                    s.as_str().ok_or_else(|| anyhow::anyhow!("spec fleet not a string"))?,
                )
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let t_th_factors = j
            .arr("t_th_factors")?
            .iter()
            .map(|x| x.as_f64().ok_or_else(|| anyhow::anyhow!("spec t_th not a number")))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(CampaignCfg {
            name: name.to_string(),
            base: ExperimentCfg::from_json(j.req("base")?)?,
            strategies,
            seeds,
            fleets,
            t_th_factors,
            checkpoint_every: j.u("checkpoint_every").unwrap_or(5),
            workers: 0,
            halt_after: None,
            halt_after_cells: None,
            verbose: false,
        })
    }
}

/// One point of the grid.
#[derive(Clone, Debug)]
pub struct CampaignCell {
    pub index: usize,
    pub strategy: String,
    pub seed: u64,
    pub fleet: FleetSpec,
    pub t_th_factor: f64,
}

impl CampaignCell {
    /// Deterministic human-readable cell name, unique within the grid.
    pub fn label(&self) -> String {
        format!(
            "{}-s{}-f{}-t{}",
            self.strategy,
            self.seed,
            self.fleet.label(),
            self.t_th_factor
        )
    }
}

/// How one cell ended up after a `run_campaign` invocation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CellRun {
    /// Already complete in the store; untouched.
    Skipped,
    /// Executed (fresh, replayed, or resumed) to completion.
    Completed,
    /// Failed — including a `halt_after` kill, whose checkpoints make the
    /// cell resumable by the next invocation.
    Failed(String),
    /// Not executed by this invocation: never claimed (campaign halted
    /// before a worker got to it), or a concurrent campaign process owns
    /// the cell's run.
    Pending,
}

#[derive(Clone, Debug)]
pub struct CellOutcome {
    pub index: usize,
    pub label: String,
    pub run_id: Option<String>,
    pub status: CellRun,
}

#[derive(Clone, Debug)]
pub struct CampaignOutcome {
    pub cells: Vec<CellOutcome>,
    /// `halt_after_cells` tripped.
    pub halted: bool,
}

impl CampaignOutcome {
    /// Every cell is done (complete in the store), whether this
    /// invocation executed it or a previous one did.
    pub fn complete(&self) -> bool {
        self.cells
            .iter()
            .all(|c| matches!(c.status, CellRun::Skipped | CellRun::Completed))
    }

    /// (skipped, completed, failed, pending) counts.
    pub fn counts(&self) -> (usize, usize, usize, usize) {
        let mut n = (0, 0, 0, 0);
        for c in &self.cells {
            match c.status {
                CellRun::Skipped => n.0 += 1,
                CellRun::Completed => n.1 += 1,
                CellRun::Failed(_) => n.2 += 1,
                CellRun::Pending => n.3 += 1,
            }
        }
        n
    }

    pub fn failures(&self) -> impl Iterator<Item = &CellOutcome> {
        self.cells.iter().filter(|c| matches!(c.status, CellRun::Failed(_)))
    }
}

/// Load the campaign's persisted state, or register it on first run. A
/// pre-existing campaign must agree on the expanded grid — resuming with
/// a *different* grid under the same name is almost certainly a mistake,
/// so it fails loudly instead of silently re-mapping cells.
fn load_or_create_manifest(
    store: &RunStore,
    cfg: &CampaignCfg,
    cells: &[CampaignCell],
) -> anyhow::Result<CampaignManifest> {
    let labels: Vec<String> = cells.iter().map(CampaignCell::label).collect();
    if store.campaign_exists(&cfg.name) {
        let m = store.load_campaign(&cfg.name)?;
        let have: Vec<&str> = m.cells.iter().map(|c| c.label.as_str()).collect();
        let want: Vec<&str> = labels.iter().map(String::as_str).collect();
        anyhow::ensure!(
            have == want,
            "campaign {:?} already exists with a different grid \
             ({} cells vs {} requested) — pick a new --name or rerun with \
             the stored spec (bare `campaign run --name {}`)",
            cfg.name,
            have.len(),
            want.len(),
            cfg.name
        );
        Ok(m)
    } else {
        let now = unix_now();
        let m = CampaignManifest {
            schema_version: CAMPAIGN_SCHEMA_VERSION,
            name: cfg.name.clone(),
            created_unix: now,
            updated_unix: now,
            spec: cfg.spec_to_json(),
            cells: labels
                .into_iter()
                .map(|label| CellState { label, run_id: None })
                .collect(),
        };
        store.save_campaign(&m)?;
        Ok(m)
    }
}

/// Execute one cell to completion, whatever state the store left it in.
/// Returns the cell's run id and how it ended up. The campaign manifest
/// on *disk* is the source of truth for cell→run assignments — it is
/// re-read here and claimed via the store's locked compare-and-swap, so
/// two campaign processes driving the same grid never clobber each
/// other's assignments or double-run a cell.
fn run_cell(
    store: &RunStore,
    cfg: &CampaignCfg,
    cell: &CampaignCell,
) -> anyhow::Result<(String, CellRun)> {
    let assigned = store.load_campaign(&cfg.name)?.cells[cell.index].run_id.clone();
    if let Some(id) = assigned {
        match store.load_manifest(&id) {
            Ok(m) if m.status == RunStatus::Complete => return Ok((id, CellRun::Skipped)),
            Ok(m) if m.checkpoint.is_some() => {
                // Mid-flight kill with a checkpoint: the existing
                // ResumeState machinery continues it bitwise-identically.
                resume_run(store, &id, cfg.checkpoint_every, &mut NullObserver)?;
                return Ok((id, CellRun::Completed));
            }
            Ok(mut m) => {
                // Claimed, then died before the first checkpoint: replay
                // from round 0 into the same run. The stored config
                // snapshot is authoritative; only this invocation's kill
                // switch is layered on.
                m.records.clear();
                m.checkpoint = None;
                m.status = RunStatus::Running;
                let strategy = m.strategy.clone();
                let mut exp_cfg = m.config.clone();
                exp_cfg.halt_after = cfg.halt_after;
                let mut exp = Experiment::build(exp_cfg)?;
                let mut ckpt = CheckpointObserver::resume(store, m, cfg.checkpoint_every);
                exp.run_from(Some(&strategy), &mut ckpt, None)?;
                if let Some(e) = ckpt.take_error() {
                    anyhow::bail!("cell {}: persisting run state failed: {e}", cell.label());
                }
                return Ok((id, CellRun::Completed));
            }
            Err(_) => {
                // Run directory hand-deleted since the assignment was
                // recorded: put a fresh run in its place. The CAS expects
                // the dead id, so a concurrent reassigner wins at most
                // once; if we lose, the winner's run is authoritative and
                // may be executing right now in another process — leave
                // it to them.
                let fresh = store.fresh_run_id(&cell.strategy, cell.seed)?;
                let winner =
                    store.claim_campaign_cell(&cfg.name, cell.index, Some(id.as_str()), &fresh)?;
                if winner != fresh {
                    return Ok((winner, CellRun::Pending));
                }
                return run_fresh_cell(store, cfg, cell, fresh);
            }
        }
    }
    // Unassigned: allocate and claim *before* the first round executes,
    // so a kill at any later point still finds the cell's run. If a
    // concurrent campaign process claimed the cell between our read and
    // the CAS, defer to its run (our reserved id stays an empty dir).
    let id = store.fresh_run_id(&cell.strategy, cell.seed)?;
    let winner = store.claim_campaign_cell(&cfg.name, cell.index, None, &id)?;
    if winner != id {
        return Ok((winner, CellRun::Pending));
    }
    run_fresh_cell(store, cfg, cell, id)
}

/// Fresh execution of a cell into an already-claimed run id.
fn run_fresh_cell(
    store: &RunStore,
    cfg: &CampaignCfg,
    cell: &CampaignCell,
    id: String,
) -> anyhow::Result<(String, CellRun)> {
    let exp_cfg = cfg.cell_cfg(cell);
    let mut exp = Experiment::build(exp_cfg)?;
    let mut ckpt = CheckpointObserver::create_as(
        store,
        &exp.cfg,
        &cell.strategy,
        cfg.checkpoint_every,
        id.clone(),
    )?;
    exp.run_from(Some(&cell.strategy), &mut ckpt, None)?;
    if let Some(e) = ckpt.take_error() {
        anyhow::bail!("cell {}: persisting run state failed: {e}", cell.label());
    }
    Ok((id, CellRun::Completed))
}

/// Run (or resume) a campaign: expand the grid, reconcile it with the
/// store's persisted state, and drive every not-yet-complete cell across
/// a bounded worker pool. Returns the per-cell outcome; the campaign is
/// done when [`CampaignOutcome::complete`] — otherwise running it again
/// picks up exactly where this invocation stopped.
pub fn run_campaign(store: &RunStore, cfg: &CampaignCfg) -> anyhow::Result<CampaignOutcome> {
    let cells = cfg.cells()?;
    // Validates grid agreement and registers the campaign; per-cell
    // assignments are re-read from disk by the workers, never from this
    // snapshot.
    let manifest = load_or_create_manifest(store, cfg, &cells)?;
    let outcomes: Mutex<Vec<CellOutcome>> = Mutex::new(
        cells
            .iter()
            .map(|c| CellOutcome {
                index: c.index,
                label: c.label(),
                run_id: manifest.cells[c.index].run_id.clone(),
                status: CellRun::Pending,
            })
            .collect(),
    );
    let queue: Mutex<VecDeque<CampaignCell>> = Mutex::new(cells.iter().cloned().collect());
    let stop = AtomicBool::new(false);
    let executed = AtomicUsize::new(0);
    let requested = match cfg.workers {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    };
    // cells() guarantees at least one cell, so the clamp is well-formed
    let workers = requested.clamp(1, cells.len());

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let cell = {
                    let mut q = queue.lock().expect("campaign queue lock poisoned");
                    q.pop_front()
                };
                let Some(cell) = cell else { break };
                let label = cell.label();
                let status = match run_cell(store, cfg, &cell) {
                    Ok((id, status)) => {
                        if cfg.verbose {
                            let verb = match status {
                                CellRun::Skipped => "already complete",
                                CellRun::Pending => "owned by another campaign process",
                                _ => "done",
                            };
                            eprintln!("[campaign {}] cell {label} -> {id}: {verb}", cfg.name);
                        }
                        {
                            let mut out =
                                outcomes.lock().expect("campaign outcomes lock poisoned");
                            out[cell.index].run_id = Some(id);
                        }
                        status
                    }
                    Err(e) => {
                        if cfg.verbose {
                            eprintln!("[campaign {}] cell {label} FAILED: {e:#}", cfg.name);
                        }
                        CellRun::Failed(format!("{e:#}"))
                    }
                };
                let was_executed = status == CellRun::Completed;
                outcomes.lock().expect("campaign outcomes lock poisoned")[cell.index].status =
                    status;
                if was_executed {
                    let n = executed.fetch_add(1, Ordering::SeqCst) + 1;
                    if cfg.halt_after_cells == Some(n) {
                        stop.store(true, Ordering::SeqCst);
                    }
                }
            });
        }
    });

    Ok(CampaignOutcome {
        cells: outcomes.into_inner().expect("campaign outcomes lock poisoned"),
        halted: stop.load(Ordering::SeqCst),
    })
}

/// One table row per cell: assignment, store status, progress, accuracy.
pub fn status_table(store: &RunStore, m: &CampaignManifest) -> Table {
    let mut t = Table::new(
        &format!("campaign {} ({} cells)", m.name, m.cells.len()),
        &["cell", "run", "status", "rounds", "final acc"],
    );
    for cell in &m.cells {
        let (run, status, rounds, acc) = match &cell.run_id {
            None => ("-".to_string(), "pending".to_string(), "-".to_string(), "-".to_string()),
            Some(id) => match store.load_manifest(id) {
                Err(_) => (id.clone(), "missing".to_string(), "-".into(), "-".into()),
                Ok(r) => {
                    let status = match (r.status, &r.checkpoint) {
                        (RunStatus::Complete, _) => "complete",
                        (RunStatus::Running, Some(_)) => "resumable",
                        (RunStatus::Running, None) => "incomplete",
                    };
                    (
                        id.clone(),
                        status.to_string(),
                        format!("{}/{}", r.records.len(), r.config.rounds),
                        r.final_acc()
                            .map(|a| format!("{:.2}%", 100.0 * a))
                            .unwrap_or_else(|| "n/a".into()),
                    )
                }
            },
        };
        t.row(vec![cell.label.clone(), run, status, rounds, acc]);
    }
    t
}

/// Whole-grid comparison: every cell with a stored run, through the
/// N-way [`compare_runs`]. The baseline is `baseline` (a run id, cell
/// label, or strategy name) when given, else the first cell running
/// "fedavg" (the paper's reference), else the first cell.
pub fn report(
    store: &RunStore,
    m: &CampaignManifest,
    target: Option<f64>,
    baseline: Option<&str>,
) -> anyhow::Result<CompareReport> {
    let mut manifests = Vec::new();
    let mut labels = Vec::new();
    for cell in &m.cells {
        if let Some(id) = &cell.run_id {
            if let Ok(run) = store.load_manifest(id) {
                manifests.push(run);
                labels.push(cell.label.as_str());
            }
        }
    }
    anyhow::ensure!(
        !manifests.is_empty(),
        "campaign {:?} has no stored runs to report on yet",
        m.name
    );
    let base_idx = match baseline {
        Some(want) => manifests
            .iter()
            .zip(&labels)
            .position(|(r, &label)| r.id == want || label == want || r.strategy == want)
            .ok_or_else(|| {
                anyhow::anyhow!("baseline {want:?} matches no cell run id, label, or strategy")
            })?,
        None => manifests
            .iter()
            .position(|r| r.strategy == "fedavg")
            .unwrap_or(0),
    };
    let refs: Vec<&crate::store::schema::RunManifest> = manifests.iter().collect();
    Ok(compare_runs(&refs, target, base_idx))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid() -> CampaignCfg {
        let base = ExperimentCfg {
            model: "mock:4x20".into(),
            rounds: 4,
            ..Default::default()
        };
        let mut cfg = CampaignCfg::new("unit", base);
        cfg.strategies = vec!["fedavg".into(), "fedel".into()];
        cfg.seeds = vec![1, 2];
        cfg
    }

    #[test]
    fn cells_expand_deterministically() {
        let cfg = grid();
        let cells = cfg.cells().unwrap();
        assert_eq!(cells.len(), 4);
        let labels: Vec<String> = cells.iter().map(CampaignCell::label).collect();
        assert_eq!(
            labels,
            vec![
                "fedavg-s1-fsmall10-t1",
                "fedavg-s2-fsmall10-t1",
                "fedel-s1-fsmall10-t1",
                "fedel-s2-fsmall10-t1",
            ]
        );
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
        // empty axis rejected
        let mut bad = grid();
        bad.seeds.clear();
        assert!(bad.cells().is_err());
    }

    #[test]
    fn cell_cfg_applies_axes_and_kill_switch() {
        let mut cfg = grid();
        cfg.halt_after = Some(2);
        let cells = cfg.cells().unwrap();
        let c = cfg.cell_cfg(&cells[3]);
        assert_eq!(c.strategy, "fedel");
        assert_eq!(c.seed, 2);
        assert_eq!(c.halt_after, Some(2));
        assert_eq!(c.model, "mock:4x20");
    }

    #[test]
    fn spec_round_trips_through_json_text() {
        let mut cfg = grid();
        cfg.fleets = vec![FleetSpec::Small10, FleetSpec::Scales(vec![1.0, 2.5])];
        cfg.t_th_factors = vec![0.8, 1.25];
        cfg.seeds = vec![(1u64 << 53) + 1, 7];
        let text = cfg.spec_to_json().to_string_pretty();
        let back = CampaignCfg::from_spec_json("unit", &Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.strategies, cfg.strategies);
        assert_eq!(back.seeds, cfg.seeds, "u64 seeds must survive the string path");
        assert_eq!(back.fleets, cfg.fleets);
        assert_eq!(back.t_th_factors, cfg.t_th_factors);
        assert_eq!(back.base.model, cfg.base.model);
        assert_eq!(
            back.cells().unwrap().iter().map(CampaignCell::label).collect::<Vec<_>>(),
            cfg.cells().unwrap().iter().map(CampaignCell::label).collect::<Vec<_>>()
        );
    }
}
