//! Campaign runner: grids of experiments as one crash-safe unit of work.
//!
//! Reproducing FedEL's headline tables means sweeping knobs against the
//! baselines — dozens of runs per figure. A [`CampaignCfg`] names such a
//! grid over the **typed parameter space** ([`crate::config::params`]):
//! each [`SweepAxis`] sweeps one registered key (`strategy`, `seed`,
//! `data.alpha`, `strategy.fedel.harmonize_weight`, ...), so any knob —
//! including strategy-declared tunables — is sweepable with no per-knob
//! code. [`run_campaign`] expands the axes into deterministic cells, fans
//! them out across a bounded worker pool, and writes every run through
//! the shared, lockfile-guarded [`RunStore`]. Per-cell configs resolve
//! with defined precedence: base config < axis bindings < the campaign's
//! `--set` overlay.
//!
//! The campaign itself is as durable as its runs:
//!
//! * The cell → run-id assignment persists in
//!   `campaigns/<name>.json` ([`crate::store::schema::CampaignManifest`]),
//!   atomically rewritten under the store lock as workers claim cells.
//!   Cell identity is the rendered axis overlay
//!   (`strategy=fedavg,seed=1`), deterministic across invocations.
//! * A killed campaign resumes by running it again (same name, same or no
//!   grid args): **complete cells are skipped**, cells with a checkpoint
//!   continue through the existing [`crate::fl::server::ResumeState`]
//!   machinery (bitwise-identical to never having stopped,
//!   `tests/campaign.rs`), and cells that died before their first
//!   checkpoint replay from round 0 into the same run.
//! * Campaign manifests written by the fixed-four-axes era (schema v1)
//!   migrate in place on the next `campaign run`: the spec converts to
//!   axes form, labels are rewritten, and run assignments survive — old
//!   campaigns stay resumable (`tests/campaign.rs`).
//! * Two kill switches mirror `ServerCfg::halt_after` for drills and
//!   tests: `halt_after` kills each executing cell after k rounds, and
//!   `halt_after_cells` stops the campaign after n cells finish.
//! * The multi-process control plane lives in [`crate::operator`]:
//!   `campaign operate` workers drive these same cells through the same
//!   store primitives — plus leases, live grid edits, and
//!   successive-halving pruning — so one-shot runs and reconcile-loop
//!   fleets are interchangeable on any campaign.
//!
//! Reporting rides the N-way [`crate::report::compare_runs`] ([`report`])
//! and, for the paper's Table-3 shape, [`grouped_report`] collapses one
//! or more axes (typically `seed`, or `seed,fleet`) into mean ± std per
//! remaining cell. Correlated knobs that should advance together rather
//! than cross-multiply ride the `--zip` group ([`CampaignCfg::zip_axis`]).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::config::params::{bindings_label, Binding, ParamSpace, ParamValue, SpecOverlay, SweepAxis};
use crate::config::ExperimentCfg;
use crate::fl::observer::{NullObserver, ObserverSet, RoundObserver};
use crate::report::{
    aggregate, compare_runs, time_to_target, CompareReport, GroupRow, GroupedReport, Table,
    Target, TargetMetric,
};
use crate::sim::experiment::{resume_run_until, Experiment};
use crate::store::checkpoint::CheckpointObserver;
use crate::store::schema::{
    CampaignManifest, CellState, RunManifest, RunStatus, CAMPAIGN_SCHEMA_VERSION,
};
use crate::store::RunStore;
use crate::util::json::Json;
use crate::util::unix_now;

/// A grid of experiments over one base config: the cross product of the
/// sweep axes, expanded in a fixed order (first axis outermost), so cell
/// indices and labels are deterministic — which is what lets an
/// interrupted campaign find its cells again.
#[derive(Clone, Debug)]
pub struct CampaignCfg {
    pub name: String,
    /// Shared knobs; each cell applies its axis bindings (then the `set`
    /// overlay) on top.
    pub base: ExperimentCfg,
    /// Grid dimensions over registered parameter keys. Empty = one cell
    /// running the base config as-is.
    pub axes: Vec<SweepAxis>,
    /// Correlated axes (`--zip`): all must have the same value count and
    /// advance together, forming ONE extra grid dimension (the innermost)
    /// whose i-th step binds every zipped key to its i-th value. Lets a
    /// sweep pair, e.g., a fleet with its matched t_th_factor without
    /// paying the cross product.
    pub zip: Vec<SweepAxis>,
    /// The CLI `--set` layer, applied after the axis bindings in every
    /// cell (precedence: base < axis < set).
    pub set: SpecOverlay,
    /// Checkpoint cadence inside each cell (rounds).
    pub checkpoint_every: usize,
    /// Concurrent cells; 0 = one per host core. Purely a wall-clock knob:
    /// cells are independent deterministic experiments, so results are
    /// identical at any worker count.
    pub workers: usize,
    /// Kill switch, per cell: every cell *executed* by this invocation
    /// halts after this absolute round (fresh, replayed, and resumed
    /// alike; a boundary the cell has already passed is inert). Never
    /// part of the spec snapshot or any run's config — the operator sets
    /// it per segment to stop cells at rung boundaries.
    pub halt_after: Option<usize>,
    /// Kill switch, campaign-level: stop claiming cells once this many
    /// have been executed to completion by this invocation. Not part of
    /// the spec snapshot.
    pub halt_after_cells: Option<usize>,
    /// Per-cell progress lines on stderr.
    pub verbose: bool,
}

impl CampaignCfg {
    /// An axis-less campaign (one cell, the base config); add dimensions
    /// with [`CampaignCfg::axis`].
    pub fn new(name: impl Into<String>, base: ExperimentCfg) -> CampaignCfg {
        CampaignCfg {
            name: name.into(),
            base,
            axes: Vec::new(),
            zip: Vec::new(),
            set: SpecOverlay::new(),
            checkpoint_every: 5,
            workers: 0,
            halt_after: None,
            halt_after_cells: None,
            verbose: false,
        }
    }

    /// Add one sweep axis from a `key=v1,v2,...` spec (the `--sweep`
    /// syntax; fleet values split on ';').
    pub fn axis(&mut self, spec: &str) -> anyhow::Result<&mut CampaignCfg> {
        self.push_axis(SweepAxis::parse(ParamSpace::shared(), spec)?)?;
        Ok(self)
    }

    fn push_axis(&mut self, axis: SweepAxis) -> anyhow::Result<()> {
        self.ensure_new_key(&axis.key)?;
        self.axes.push(axis);
        Ok(())
    }

    /// Add one correlated axis from a `key=v1,v2,...` spec (the `--zip`
    /// syntax, same grammar as `--sweep`). All zipped axes advance
    /// together as one grid dimension; [`CampaignCfg::cells`] rejects the
    /// campaign loudly if their value counts disagree.
    pub fn zip_axis(&mut self, spec: &str) -> anyhow::Result<&mut CampaignCfg> {
        let axis = SweepAxis::parse(ParamSpace::shared(), spec)?;
        self.ensure_new_key(&axis.key)?;
        self.zip.push(axis);
        Ok(self)
    }

    fn ensure_new_key(&self, key: &str) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.axes.iter().chain(&self.zip).any(|a| a.key == key),
            "campaign {:?}: axis {:?} specified twice",
            self.name,
            key
        );
        Ok(())
    }

    /// The grid, expanded in deterministic order (first axis outermost;
    /// the zip group, when present, is the single innermost dimension).
    pub fn cells(&self) -> anyhow::Result<Vec<CampaignCell>> {
        anyhow::ensure!(self.checkpoint_every >= 1, "checkpoint interval must be >= 1");
        for axis in self.axes.iter().chain(&self.zip) {
            anyhow::ensure!(
                !axis.values.is_empty(),
                "campaign {:?}: axis {:?} has no values",
                self.name,
                axis.key
            );
            anyhow::ensure!(
                self.axes.iter().chain(&self.zip).filter(|a| a.key == axis.key).count() == 1,
                "campaign {:?}: axis {:?} specified twice",
                self.name,
                axis.key
            );
        }
        if let Some(first) = self.zip.first() {
            for axis in &self.zip[1..] {
                anyhow::ensure!(
                    axis.values.len() == first.values.len(),
                    "campaign {:?}: zipped axes must pair value-for-value, but {:?} has {} \
                     values while {:?} has {}",
                    self.name,
                    axis.key,
                    axis.values.len(),
                    first.key,
                    first.values.len()
                );
            }
        }
        let mut cells = vec![CampaignCell { index: 0, bindings: Vec::new() }];
        for axis in &self.axes {
            let mut next = Vec::with_capacity(cells.len() * axis.values.len());
            for cell in &cells {
                for v in &axis.values {
                    let mut bindings = cell.bindings.clone();
                    bindings.push(Binding { key: axis.key.clone(), value: v.clone() });
                    next.push(CampaignCell { index: next.len(), bindings });
                }
            }
            cells = next;
        }
        if let Some(first) = self.zip.first() {
            let mut next = Vec::with_capacity(cells.len() * first.values.len());
            for cell in &cells {
                for step in 0..first.values.len() {
                    let mut bindings = cell.bindings.clone();
                    for axis in &self.zip {
                        bindings.push(Binding {
                            key: axis.key.clone(),
                            value: axis.values[step].clone(),
                        });
                    }
                    next.push(CampaignCell { index: next.len(), bindings });
                }
            }
            cells = next;
        }
        for (i, c) in cells.iter_mut().enumerate() {
            c.index = i;
        }
        Ok(cells)
    }

    /// The experiment a cell runs: base config, the cell's axis bindings,
    /// then the `set` overlay (plus this invocation's kill switch).
    pub fn cell_cfg(&self, cell: &CampaignCell) -> anyhow::Result<ExperimentCfg> {
        let space = ParamSpace::shared();
        let mut cfg = self.base.clone();
        for b in &cell.bindings {
            space.resolve(&b.key)?.apply(&mut cfg, &b.value)?;
        }
        self.set.apply(space, &mut cfg)?;
        cfg.halt_after = self.halt_after;
        cfg.verbose = false;
        cfg.record_selections = false;
        Ok(cfg)
    }

    /// Grid spec snapshot for the campaign manifest (schema v2). Process
    /// knobs (workers, kill switches, verbosity) stay out, like
    /// `ExperimentCfg::to_json` keeps `halt_after` out of run snapshots.
    pub fn spec_to_json(&self) -> Json {
        let mut spec = vec![
            ("base", self.base.to_json()),
            ("set", self.set.to_json()),
            ("axes", Json::Arr(self.axes.iter().map(SweepAxis::to_json).collect())),
        ];
        // Only written when used, so pre-zip specs re-serialize textually
        // identical (their stored manifests keep matching byte-for-byte).
        if !self.zip.is_empty() {
            spec.push(("zip", Json::Arr(self.zip.iter().map(SweepAxis::to_json).collect())));
        }
        spec.push(("checkpoint_every", Json::Num(self.checkpoint_every as f64)));
        Json::obj(spec)
    }

    /// Rebuild a grid from a manifest's spec snapshot (the bare
    /// `campaign run --name <x>` resume path). Accepts both the current
    /// axes form and the v1 fixed-four-axes form, which converts to the
    /// equivalent `strategy` / `seed` / `fleet` / `time.t_th_factor`
    /// axes in the original nesting order — cell index i maps to cell i.
    pub fn from_spec_json(name: &str, j: &Json) -> anyhow::Result<CampaignCfg> {
        let mut cfg = CampaignCfg::new(name.to_string(), ExperimentCfg::from_json(j.req("base")?)?);
        cfg.checkpoint_every = j.u("checkpoint_every").unwrap_or(5);
        if j.get("strategies").is_some() {
            // v1 spec: four fixed arrays.
            let strategies = j
                .arr("strategies")?
                .iter()
                .map(|s| {
                    s.as_str()
                        .map(|s| ParamValue::Str(s.to_string()))
                        .ok_or_else(|| anyhow::anyhow!("spec strategy not a string"))
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            let seeds = j
                .arr("seeds")?
                .iter()
                .map(|s| match s {
                    Json::Str(s) => s
                        .parse()
                        .map(ParamValue::U64)
                        .map_err(|e| anyhow::anyhow!("spec seed {s:?}: {e}")),
                    Json::Num(x) => Ok(ParamValue::U64(*x as u64)),
                    other => anyhow::bail!("spec seed {other:?} not a number or string"),
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            let fleets = j
                .arr("fleets")?
                .iter()
                .map(|s| {
                    let s = s
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("spec fleet not a string"))?;
                    Ok(ParamValue::Fleet(crate::config::FleetSpec::parse(s)?))
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            let t_ths = j
                .arr("t_th_factors")?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .map(ParamValue::F64)
                        .ok_or_else(|| anyhow::anyhow!("spec t_th not a number"))
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            for (key, values) in [
                ("strategy", strategies),
                ("seed", seeds),
                ("fleet", fleets),
                ("time.t_th_factor", t_ths),
            ] {
                anyhow::ensure!(!values.is_empty(), "v1 spec axis {key} is empty");
                cfg.push_axis(SweepAxis { key: key.to_string(), values })?;
            }
            return Ok(cfg);
        }
        let space = ParamSpace::shared();
        cfg.set = match j.get("set") {
            None => SpecOverlay::new(),
            Some(v) => SpecOverlay::from_json(space, v)?,
        };
        for axis in j.arr("axes")? {
            cfg.push_axis(SweepAxis::from_json(space, axis)?)?;
        }
        if let Some(Json::Arr(zipped)) = j.get("zip") {
            for axis in zipped {
                let axis = SweepAxis::from_json(space, axis)?;
                cfg.ensure_new_key(&axis.key)?;
                cfg.zip.push(axis);
            }
        }
        Ok(cfg)
    }
}

/// One point of the grid: its index in expansion order and the axis
/// bindings that define it.
#[derive(Clone, Debug)]
pub struct CampaignCell {
    pub index: usize,
    pub bindings: Vec<Binding>,
}

impl CampaignCell {
    /// Deterministic cell identity, unique within the grid: the rendered
    /// axis overlay (`strategy=fedavg,seed=1`; "base" for an axis-less
    /// campaign).
    pub fn label(&self) -> String {
        bindings_label(&self.bindings)
    }
}

/// How one cell ended up after a `run_campaign` invocation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CellRun {
    /// Already complete in the store; untouched.
    Skipped,
    /// Executed (fresh, replayed, or resumed) to completion.
    Completed,
    /// Failed — including a `halt_after` kill, whose checkpoints make the
    /// cell resumable by the next invocation.
    Failed(String),
    /// Not executed by this invocation: never claimed (campaign halted
    /// before a worker got to it), or a concurrent campaign process owns
    /// the cell's run.
    Pending,
    /// Retired by the successive-halving policy
    /// ([`crate::operator::policy`]): the cell ranked below the keep
    /// fraction at a rung boundary and will never be advanced again. Its
    /// partial run (if any) stays in the store for reporting.
    Pruned,
}

#[derive(Clone, Debug)]
pub struct CellOutcome {
    pub index: usize,
    pub label: String,
    pub run_id: Option<String>,
    pub status: CellRun,
}

#[derive(Clone, Debug)]
pub struct CampaignOutcome {
    pub cells: Vec<CellOutcome>,
    /// `halt_after_cells` tripped.
    pub halted: bool,
}

impl CampaignOutcome {
    /// Every cell is done — complete in the store (whether this
    /// invocation executed it or a previous one did) or retired by the
    /// halving policy.
    pub fn complete(&self) -> bool {
        self.cells
            .iter()
            .all(|c| matches!(c.status, CellRun::Skipped | CellRun::Completed | CellRun::Pruned))
    }

    /// (skipped, completed, failed, pending, pruned) counts.
    pub fn counts(&self) -> (usize, usize, usize, usize, usize) {
        let mut n = (0, 0, 0, 0, 0);
        for c in &self.cells {
            match c.status {
                CellRun::Skipped => n.0 += 1,
                CellRun::Completed => n.1 += 1,
                CellRun::Failed(_) => n.2 += 1,
                CellRun::Pending => n.3 += 1,
                CellRun::Pruned => n.4 += 1,
            }
        }
        n
    }

    pub fn failures(&self) -> impl Iterator<Item = &CellOutcome> {
        self.cells.iter().filter(|c| matches!(c.status, CellRun::Failed(_)))
    }
}

/// Upgrade a v1 campaign manifest in place: the spec converts to axes
/// form and every cell label is rewritten to the overlay rendering, with
/// run assignments preserved by index (v1 expansion order == the
/// converted axes' expansion order). Runs as one locked transaction
/// ([`RunStore::update_campaign`]) so a concurrent campaign process
/// claiming cells — or migrating too — can never lose writes: the
/// manifest is re-read under the lock, and a raced migration that
/// already upgraded it is a no-op.
pub(crate) fn migrate_campaign(store: &RunStore, name: &str) -> anyhow::Result<CampaignManifest> {
    store.update_campaign(name, |mut m| {
        if m.schema_version >= CAMPAIGN_SCHEMA_VERSION {
            return Ok(m); // another process migrated between our load and lock
        }
        let cfg = CampaignCfg::from_spec_json(&m.name, &m.spec)
            .map_err(|e| anyhow::anyhow!("campaign {:?}: migrating v1 spec: {e}", m.name))?;
        let cells = cfg.cells()?;
        anyhow::ensure!(
            cells.len() == m.cells.len(),
            "campaign {:?}: v1 manifest has {} cells but its spec expands to {}",
            m.name,
            m.cells.len(),
            cells.len()
        );
        for (cell, state) in cells.iter().zip(m.cells.iter_mut()) {
            state.label = cell.label();
        }
        m.spec = cfg.spec_to_json();
        m.schema_version = CAMPAIGN_SCHEMA_VERSION;
        m.updated_unix = unix_now();
        Ok(m)
    })
}

/// Load the campaign's persisted state, or register it on first run. A
/// pre-existing campaign must agree on the expanded grid — resuming with
/// a *different* grid under the same name is almost certainly a mistake,
/// so it fails loudly instead of silently re-mapping cells. Manifests
/// from older schema versions are migrated first. (The operator's
/// reconcile loop shares this entry point, so `campaign run` and
/// `campaign operate` register and resume campaigns identically.)
pub(crate) fn load_or_create_manifest(
    store: &RunStore,
    cfg: &CampaignCfg,
    cells: &[CampaignCell],
) -> anyhow::Result<CampaignManifest> {
    let labels: Vec<String> = cells.iter().map(CampaignCell::label).collect();
    if store.campaign_exists(&cfg.name) {
        let mut m = store.load_campaign(&cfg.name)?;
        if m.schema_version < CAMPAIGN_SCHEMA_VERSION {
            m = migrate_campaign(store, &cfg.name)?;
        }
        let have: Vec<&str> = m.cells.iter().map(|c| c.label.as_str()).collect();
        let want: Vec<&str> = labels.iter().map(String::as_str).collect();
        anyhow::ensure!(
            have == want,
            "campaign {:?} already exists with a different grid \
             ({} cells vs {} requested) — pick a new --name or rerun with \
             the stored spec (bare `campaign run --name {}`)",
            cfg.name,
            have.len(),
            want.len(),
            cfg.name
        );
        Ok(m)
    } else {
        let now = unix_now();
        let m = CampaignManifest {
            schema_version: CAMPAIGN_SCHEMA_VERSION,
            name: cfg.name.clone(),
            created_unix: now,
            updated_unix: now,
            spec: cfg.spec_to_json(),
            cells: labels.into_iter().map(CellState::unassigned).collect(),
        };
        store.save_campaign(&m)?;
        Ok(m)
    }
}

/// Execute one cell as far as this invocation's kill switch allows,
/// whatever state the store left it in. Returns the cell's run id (when
/// it has one) and how it ended up. The campaign manifest on *disk* is
/// the source of truth for cell→run assignments — it is re-read here
/// (cells addressed by label, which live grid edits keep stable) and
/// claimed via the store's locked compare-and-swap, so two campaign
/// processes driving the same grid never clobber each other's
/// assignments or double-run a cell. `extra` rides every executed round
/// (the operator's lease heartbeat; `NullObserver` for plain
/// `campaign run`).
pub(crate) fn run_cell(
    store: &RunStore,
    cfg: &CampaignCfg,
    cell: &CampaignCell,
    extra: &mut dyn RoundObserver,
) -> anyhow::Result<(Option<String>, CellRun)> {
    let label = cell.label();
    let state = store
        .load_campaign(&cfg.name)?
        .cells
        .into_iter()
        .find(|c| c.label == label)
        .ok_or_else(|| anyhow::anyhow!("campaign {:?} has no cell {label:?}", cfg.name))?;
    if state.pruned {
        return Ok((state.run_id, CellRun::Pruned));
    }
    if let Some(id) = state.run_id {
        match store.load_manifest(&id) {
            Ok(m) if m.status == RunStatus::Complete => {
                return Ok((Some(id), CellRun::Skipped))
            }
            Ok(m) if m.checkpoint.is_some() => {
                // Mid-flight kill with a checkpoint: the existing
                // ResumeState machinery continues it bitwise-identically,
                // up to this invocation's kill switch (None = completion).
                resume_run_until(store, &id, cfg.checkpoint_every, cfg.halt_after, extra)?;
                return Ok((Some(id), CellRun::Completed));
            }
            Ok(mut m) => {
                // Claimed, then died before the first checkpoint: replay
                // from round 0 into the same run. The stored config
                // snapshot is authoritative; only this invocation's kill
                // switch is layered on.
                m.records.clear();
                m.checkpoint = None;
                m.status = RunStatus::Running;
                let strategy = m.strategy.clone();
                let mut exp_cfg = m.config.clone();
                exp_cfg.halt_after = cfg.halt_after;
                let mut exp = Experiment::build(exp_cfg)?;
                let mut ckpt = CheckpointObserver::resume(store, m, cfg.checkpoint_every);
                {
                    let mut set = ObserverSet::new();
                    set.push(&mut ckpt);
                    set.push(extra);
                    exp.run_from(Some(&strategy), &mut set, None)?;
                }
                if let Some(e) = ckpt.take_error() {
                    anyhow::bail!("cell {label}: persisting run state failed: {e}");
                }
                return Ok((Some(id), CellRun::Completed));
            }
            Err(_) => {
                // Run directory hand-deleted since the assignment was
                // recorded: put a fresh run in its place. The CAS expects
                // the dead id, so a concurrent reassigner wins at most
                // once; if we lose, the winner's run is authoritative and
                // may be executing right now in another process — leave
                // it to them.
                let exp_cfg = cfg.cell_cfg(cell)?;
                let fresh = store.fresh_run_id(&exp_cfg.strategy, exp_cfg.seed)?;
                let winner =
                    store.claim_campaign_cell(&cfg.name, &label, Some(id.as_str()), &fresh)?;
                if winner != fresh {
                    return Ok((Some(winner), CellRun::Pending));
                }
                return run_fresh_cell(store, cfg, cell, exp_cfg, fresh, extra);
            }
        }
    }
    // Unassigned: allocate and claim *before* the first round executes,
    // so a kill at any later point still finds the cell's run. If a
    // concurrent campaign process claimed the cell between our read and
    // the CAS, defer to its run (our reserved id stays an empty dir).
    let exp_cfg = cfg.cell_cfg(cell)?;
    let id = store.fresh_run_id(&exp_cfg.strategy, exp_cfg.seed)?;
    let winner = store.claim_campaign_cell(&cfg.name, &label, None, &id)?;
    if winner != id {
        return Ok((Some(winner), CellRun::Pending));
    }
    run_fresh_cell(store, cfg, cell, exp_cfg, id, extra)
}

/// Fresh execution of a cell into an already-claimed run id.
fn run_fresh_cell(
    store: &RunStore,
    cfg: &CampaignCfg,
    cell: &CampaignCell,
    exp_cfg: ExperimentCfg,
    id: String,
    extra: &mut dyn RoundObserver,
) -> anyhow::Result<(Option<String>, CellRun)> {
    let strategy = exp_cfg.strategy.clone();
    let mut exp = Experiment::build(exp_cfg)?;
    let mut ckpt = CheckpointObserver::create_as(
        store,
        &exp.cfg,
        &strategy,
        cfg.checkpoint_every,
        id.clone(),
    )?;
    {
        let mut set = ObserverSet::new();
        set.push(&mut ckpt);
        set.push(extra);
        exp.run_from(Some(&strategy), &mut set, None)?;
    }
    if let Some(e) = ckpt.take_error() {
        anyhow::bail!("cell {}: persisting run state failed: {e}", cell.label());
    }
    Ok((Some(id), CellRun::Completed))
}

/// Run (or resume) a campaign: expand the grid, reconcile it with the
/// store's persisted state, and drive every not-yet-complete cell across
/// a bounded worker pool. Returns the per-cell outcome; the campaign is
/// done when [`CampaignOutcome::complete`] — otherwise running it again
/// picks up exactly where this invocation stopped.
pub fn run_campaign(store: &RunStore, cfg: &CampaignCfg) -> anyhow::Result<CampaignOutcome> {
    let cells = cfg.cells()?;
    // Validates grid agreement and registers the campaign; per-cell
    // assignments are re-read from disk by the workers, never from this
    // snapshot.
    let manifest = load_or_create_manifest(store, cfg, &cells)?;
    let outcomes: Mutex<Vec<CellOutcome>> = Mutex::new(
        cells
            .iter()
            .map(|c| CellOutcome {
                index: c.index,
                label: c.label(),
                run_id: manifest.cells[c.index].run_id.clone(),
                status: CellRun::Pending,
            })
            .collect(),
    );
    let queue: Mutex<VecDeque<CampaignCell>> = Mutex::new(cells.iter().cloned().collect());
    let stop = AtomicBool::new(false);
    let executed = AtomicUsize::new(0);
    let requested = match cfg.workers {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    };
    // cells() always yields at least one cell, so the clamp is well-formed
    let workers = requested.clamp(1, cells.len());

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let cell = {
                    let mut q = queue.lock().expect("campaign queue lock poisoned");
                    q.pop_front()
                };
                let Some(cell) = cell else { break };
                let label = cell.label();
                let status = match run_cell(store, cfg, &cell, &mut NullObserver) {
                    Ok((id, status)) => {
                        if cfg.verbose {
                            let verb = match status {
                                CellRun::Skipped => "already complete",
                                CellRun::Pending => "owned by another campaign process",
                                CellRun::Pruned => "pruned by the halving policy",
                                _ => "done",
                            };
                            let id = id.as_deref().unwrap_or("-");
                            eprintln!("[campaign {}] cell {label} -> {id}: {verb}", cfg.name);
                        }
                        if let Some(id) = id {
                            let mut out =
                                outcomes.lock().expect("campaign outcomes lock poisoned");
                            out[cell.index].run_id = Some(id);
                        }
                        status
                    }
                    Err(e) => {
                        if cfg.verbose {
                            eprintln!("[campaign {}] cell {label} FAILED: {e:#}", cfg.name);
                        }
                        CellRun::Failed(format!("{e:#}"))
                    }
                };
                let was_executed = status == CellRun::Completed;
                outcomes.lock().expect("campaign outcomes lock poisoned")[cell.index].status =
                    status;
                if was_executed {
                    let n = executed.fetch_add(1, Ordering::SeqCst) + 1;
                    if cfg.halt_after_cells == Some(n) {
                        stop.store(true, Ordering::SeqCst);
                    }
                }
            });
        }
    });

    Ok(CampaignOutcome {
        cells: outcomes.into_inner().expect("campaign outcomes lock poisoned"),
        halted: stop.load(Ordering::SeqCst),
    })
}

/// One table row per cell: assignment, store status, worker lease,
/// progress, accuracy — the [`crate::operator::status::observe`]
/// snapshot rendered for terminals. Run manifests load across a thread
/// pool there, so a wide campaign against an HTTP store costs one
/// round-trip of wall clock, not O(cells × RTT).
pub fn status_table(store: &RunStore, m: &CampaignManifest) -> Table {
    let status = crate::operator::status::observe(store, m);
    let mut t = Table::new(
        &format!("campaign {} ({} cells)", m.name, m.cells.len()),
        &["cell", "run", "status", "worker", "rounds", "final acc"],
    );
    for c in &status.cells {
        let state = if c.pruned { "pruned" } else { c.state };
        let worker = match (&c.worker, c.lease_age_secs) {
            (Some(w), Some(age)) => format!("{w} ({age}s)"),
            (Some(w), None) => w.clone(),
            (None, _) => "-".into(),
        };
        let (rounds, acc) = match (&c.run, c.rounds_total) {
            (Some(_), Some(total)) => (
                format!("{}/{total}", c.rounds_done),
                c.final_acc
                    .map(|a| format!("{:.2}%", 100.0 * a))
                    .unwrap_or_else(|| "n/a".into()),
            ),
            // pending or missing: no readable run to report on
            _ => ("-".to_string(), "-".to_string()),
        };
        t.row(vec![
            c.label.clone(),
            c.run_id.clone().unwrap_or_else(|| "-".into()),
            state.to_string(),
            worker,
            rounds,
            acc,
        ]);
    }
    t
}

/// Whole-grid comparison: every cell with a stored run, through the
/// N-way [`compare_runs`]. The baseline is `baseline` (a run id, cell
/// label, or strategy name) when given, else the first cell running
/// "fedavg" (the paper's reference), else the first cell.
pub fn report(
    store: &RunStore,
    m: &CampaignManifest,
    target: Target,
    baseline: Option<&str>,
) -> anyhow::Result<CompareReport> {
    let mut manifests = Vec::new();
    let mut labels = Vec::new();
    for cell in &m.cells {
        if let Some(id) = &cell.run_id {
            if let Ok(run) = store.load_manifest(id) {
                manifests.push(run);
                labels.push(cell.label.as_str());
            }
        }
    }
    anyhow::ensure!(
        !manifests.is_empty(),
        "campaign {:?} has no stored runs to report on yet",
        m.name
    );
    let base_idx = match baseline {
        Some(want) => manifests
            .iter()
            .zip(&labels)
            .position(|(r, &label)| r.id == want || label == want || r.strategy == want)
            .ok_or_else(|| {
                anyhow::anyhow!("baseline {want:?} matches no cell run id, label, or strategy")
            })?,
        None => manifests
            .iter()
            .position(|r| r.strategy == "fedavg")
            .unwrap_or(0),
    };
    let refs: Vec<&RunManifest> = manifests.iter().collect();
    Ok(compare_runs(&refs, target, base_idx))
}

/// The paper's Table-3 shape: collapse one or more axes (`over`, a
/// comma-separated key list, typically `seed` or `seed,fleet`) into
/// mean ± std per remaining cell — final accuracy, time-to-target, and
/// speedup vs the matched baseline cell (same remaining bindings, the
/// baseline strategy, same collapsed-axis values). `baseline` names a
/// strategy on the grid's `strategy` axis; it defaults to "fedavg" when
/// swept, else speedup columns are N/A.
pub fn grouped_report(
    store: &RunStore,
    m: &CampaignManifest,
    over: &str,
    target: Target,
    baseline: Option<&str>,
) -> anyhow::Result<GroupedReport> {
    let cfg = CampaignCfg::from_spec_json(&m.name, &m.spec)?;
    let over_keys: Vec<&str> = over.split(',').map(str::trim).filter(|k| !k.is_empty()).collect();
    anyhow::ensure!(!over_keys.is_empty(), "--over needs at least one axis key");
    for key in &over_keys {
        anyhow::ensure!(
            cfg.axes.iter().chain(&cfg.zip).any(|a| a.key == *key),
            "campaign {:?} has no {key:?} axis to aggregate over (axes: {})",
            m.name,
            cfg.axes
                .iter()
                .chain(&cfg.zip)
                .map(|a| a.key.as_str())
                .collect::<Vec<_>>()
                .join(", ")
        );
    }
    let cells = cfg.cells()?;
    anyhow::ensure!(
        cells.len() == m.cells.len(),
        "campaign {:?}: manifest has {} cells but its spec expands to {}",
        m.name,
        m.cells.len(),
        cells.len()
    );

    // Stored runs by cell index; a label -> index map for baseline lookup.
    let mut runs: Vec<Option<RunManifest>> = Vec::with_capacity(cells.len());
    let mut index_of = std::collections::HashMap::new();
    for cell in &cells {
        index_of.insert(cell.label(), cell.index);
        runs.push(
            m.cells[cell.index]
                .run_id
                .as_ref()
                .and_then(|id| store.load_manifest(id).ok()),
        );
    }
    anyhow::ensure!(
        runs.iter().any(Option::is_some),
        "campaign {:?} has no stored runs to report on yet",
        m.name
    );

    // Resolve the target once, over every stored run (compare_runs'
    // Default rule, grid-wide).
    let (metric, target) = match target {
        Target::Acc(a) => (TargetMetric::Acc, a),
        Target::Loss(l) => (TargetMetric::Loss, l),
        Target::Default => {
            let least = runs
                .iter()
                .flatten()
                .map(|r| r.final_acc().unwrap_or(0.0))
                .fold(f64::INFINITY, f64::min);
            (TargetMetric::Acc, 0.95 * least)
        }
    };

    // Baseline strategy: explicit, else "fedavg" if the strategy axis
    // sweeps it, else none (no speedup columns).
    let strategy_axis = cfg.axes.iter().chain(&cfg.zip).find(|a| a.key == "strategy");
    let baseline = match baseline {
        Some(b) => {
            let axis = strategy_axis.ok_or_else(|| {
                anyhow::anyhow!("campaign {:?} has no strategy axis to take a baseline from", m.name)
            })?;
            anyhow::ensure!(
                axis.values.iter().any(|v| v.render() == b),
                "baseline strategy {b:?} is not on the strategy axis",
            );
            Some(b.to_string())
        }
        None => strategy_axis
            .and_then(|a| a.values.iter().find(|v| v.render() == "fedavg"))
            .map(|v| v.render()),
    };

    // The matched baseline cell of a member: same bindings, with the
    // strategy binding swapped for the baseline strategy.
    let baseline_tta = |cell: &CampaignCell| -> Option<f64> {
        let base = baseline.as_deref()?;
        let mut bindings = cell.bindings.clone();
        let slot = bindings.iter_mut().find(|b| b.key == "strategy")?;
        slot.value = ParamValue::Str(base.to_string());
        let idx = *index_of.get(&bindings_label(&bindings))?;
        runs[idx]
            .as_ref()
            .and_then(|r| time_to_target(&r.records, metric, target))
    };

    // Group cells by their bindings minus the collapsed axes, in
    // first-seen (expansion) order.
    let mut order: Vec<String> = Vec::new();
    let mut groups: std::collections::HashMap<String, Vec<usize>> = std::collections::HashMap::new();
    for cell in &cells {
        let rest: Vec<Binding> = cell
            .bindings
            .iter()
            .filter(|b| !over_keys.contains(&b.key.as_str()))
            .cloned()
            .collect();
        let label = bindings_label(&rest);
        if !groups.contains_key(&label) {
            order.push(label.clone());
        }
        groups.entry(label).or_default().push(cell.index);
    }

    let rows = order
        .into_iter()
        .map(|label| {
            let members = &groups[&label];
            let mut accs = Vec::new();
            let mut ttas = Vec::new();
            let mut speedups = Vec::new();
            let mut stored = 0;
            for &idx in members {
                let Some(run) = &runs[idx] else { continue };
                stored += 1;
                if let Some(a) = run.final_acc() {
                    accs.push(a);
                }
                let tta = time_to_target(&run.records, metric, target);
                if let Some(t) = tta {
                    ttas.push(t);
                    if let Some(tb) = baseline_tta(&cells[idx]) {
                        speedups.push(tb / t.max(1e-9));
                    }
                }
            }
            GroupRow {
                label,
                cells: stored,
                final_acc: aggregate(&accs),
                time_to_target: aggregate(&ttas),
                speedup_vs_baseline: aggregate(&speedups),
            }
        })
        .collect();

    Ok(GroupedReport { metric, target, over: over_keys.join(","), baseline, rows })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid() -> CampaignCfg {
        let base = ExperimentCfg {
            model: "mock:4x20".into(),
            rounds: 4,
            ..Default::default()
        };
        let mut cfg = CampaignCfg::new("unit", base);
        cfg.axis("strategy=fedavg,fedel").unwrap();
        cfg.axis("seed=1,2").unwrap();
        cfg
    }

    #[test]
    fn cells_expand_deterministically() {
        let cfg = grid();
        let cells = cfg.cells().unwrap();
        assert_eq!(cells.len(), 4);
        let labels: Vec<String> = cells.iter().map(CampaignCell::label).collect();
        assert_eq!(
            labels,
            vec![
                "strategy=fedavg,seed=1",
                "strategy=fedavg,seed=2",
                "strategy=fedel,seed=1",
                "strategy=fedel,seed=2",
            ]
        );
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
        // an axis-less campaign is one base cell
        let solo = CampaignCfg::new("solo", ExperimentCfg::default());
        let cells = solo.cells().unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].label(), "base");
        // duplicate axes rejected
        let mut dup = grid();
        assert!(dup.axis("seed=3").is_err());
    }

    #[test]
    fn zipped_axes_pair_positionally_as_one_inner_dimension() {
        let mut cfg = CampaignCfg::new("zip", ExperimentCfg::default());
        cfg.axis("seed=1,2").unwrap();
        cfg.zip_axis("fleet=small10;large20").unwrap();
        cfg.zip_axis("time.t_th_factor=0.8,1.25").unwrap();
        let cells = cfg.cells().unwrap();
        // 2 seeds x 2 zip steps — NOT the 2x2x2 cross product
        assert_eq!(cells.len(), 4);
        let labels: Vec<String> = cells.iter().map(CampaignCell::label).collect();
        assert_eq!(
            labels,
            vec![
                "seed=1,fleet=small10,time.t_th_factor=0.8",
                "seed=1,fleet=large20,time.t_th_factor=1.25",
                "seed=2,fleet=small10,time.t_th_factor=0.8",
                "seed=2,fleet=large20,time.t_th_factor=1.25",
            ]
        );
        // zip bindings resolve into the cell config like any axis binding
        let c = cfg.cell_cfg(&cells[1]).unwrap();
        assert_eq!(c.t_th_factor, 1.25);
    }

    #[test]
    fn zip_length_mismatch_and_duplicate_keys_fail_loudly() {
        let mut cfg = CampaignCfg::new("zip", ExperimentCfg::default());
        cfg.zip_axis("seed=1,2,3").unwrap();
        cfg.zip_axis("time.t_th_factor=0.8,1.25").unwrap();
        let err = cfg.cells().unwrap_err().to_string();
        assert!(err.contains("pair value-for-value"), "{err}");
        assert!(err.contains("2") && err.contains("3"), "counts missing: {err}");
        // a key can't appear in both --sweep and --zip
        let mut dup = CampaignCfg::new("zip", ExperimentCfg::default());
        dup.axis("seed=1,2").unwrap();
        assert!(dup.zip_axis("seed=3,4").is_err());
        let mut dup = CampaignCfg::new("zip", ExperimentCfg::default());
        dup.zip_axis("seed=1,2").unwrap();
        assert!(dup.axis("seed=3,4").is_err());
    }

    #[test]
    fn zip_survives_the_spec_snapshot_and_stays_out_when_unused() {
        let mut cfg = grid();
        cfg.zip_axis("data.alpha=0.1,0.5").unwrap();
        cfg.zip_axis("time.t_th_factor=0.8,1.25").unwrap();
        let text = cfg.spec_to_json().to_string_pretty();
        let back = CampaignCfg::from_spec_json("unit", &Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.zip, cfg.zip);
        assert_eq!(
            back.cells().unwrap().iter().map(CampaignCell::label).collect::<Vec<_>>(),
            cfg.cells().unwrap().iter().map(CampaignCell::label).collect::<Vec<_>>()
        );
        // pre-zip campaigns keep serializing without the key at all
        assert!(grid().spec_to_json().get("zip").is_none());
    }

    #[test]
    fn cell_cfg_applies_axes_set_and_kill_switch() {
        let mut cfg = grid();
        cfg.halt_after = Some(2);
        cfg.axis("data.alpha=0.1,0.5").unwrap();
        cfg.axis("strategy.fedel.harmonize_weight=0.4,0.8").unwrap();
        let cells = cfg.cells().unwrap();
        assert_eq!(cells.len(), 16);
        let c = cfg.cell_cfg(&cells[15]).unwrap();
        assert_eq!(c.strategy, "fedel");
        assert_eq!(c.seed, 2);
        assert_eq!(c.alpha, 0.5);
        assert_eq!(
            c.strategy_params,
            vec![("strategy.fedel.harmonize_weight".to_string(), 0.8)]
        );
        assert_eq!(c.halt_after, Some(2));
        assert_eq!(c.model, "mock:4x20");
        // the --set layer wins over an axis binding for the same key
        let space = ParamSpace::shared();
        let mut with_set = grid();
        with_set.set = SpecOverlay::parse(space, &["seed=9", "train.lr=0.25"]).unwrap();
        let cells = with_set.cells().unwrap();
        let c = with_set.cell_cfg(&cells[0]).unwrap();
        assert_eq!(c.seed, 9, "--set beats the seed axis");
        assert_eq!(c.lr, 0.25);
    }

    #[test]
    fn spec_round_trips_through_json_text() {
        let mut cfg = grid();
        cfg.axis("fleet=small10;1,2.5").unwrap();
        cfg.axis("time.t_th_factor=0.8,1.25").unwrap();
        cfg.axis("strategy.fedel.harmonize_weight=0.4,0.6").unwrap();
        cfg.set = SpecOverlay::parse(ParamSpace::shared(), &["train.lr=0.125"]).unwrap();
        let text = cfg.spec_to_json().to_string_pretty();
        let back = CampaignCfg::from_spec_json("unit", &Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.axes, cfg.axes);
        assert_eq!(back.set, cfg.set);
        assert_eq!(back.base.model, cfg.base.model);
        assert_eq!(
            back.cells().unwrap().iter().map(CampaignCell::label).collect::<Vec<_>>(),
            cfg.cells().unwrap().iter().map(CampaignCell::label).collect::<Vec<_>>()
        );
    }

    #[test]
    fn v1_spec_converts_to_equivalent_axes() {
        // A spec exactly as PR-3-era code persisted it.
        let v1 = Json::parse(
            r#"{
                "base": {"model": "mock:4x20", "rounds": 4, "seed": "42"},
                "strategies": ["fedavg", "fedel"],
                "seeds": ["1", "2"],
                "fleets": ["small10"],
                "t_th_factors": [1],
                "checkpoint_every": 2
            }"#,
        )
        .unwrap();
        let cfg = CampaignCfg::from_spec_json("legacy", &v1).unwrap();
        assert_eq!(cfg.checkpoint_every, 2);
        assert_eq!(cfg.axes.len(), 4);
        let labels: Vec<String> =
            cfg.cells().unwrap().iter().map(CampaignCell::label).collect();
        assert_eq!(
            labels,
            vec![
                "strategy=fedavg,seed=1,fleet=small10,time.t_th_factor=1",
                "strategy=fedavg,seed=2,fleet=small10,time.t_th_factor=1",
                "strategy=fedel,seed=1,fleet=small10,time.t_th_factor=1",
                "strategy=fedel,seed=2,fleet=small10,time.t_th_factor=1",
            ]
        );
        // converted specs re-serialize in v2 form
        let v2 = cfg.spec_to_json();
        assert!(v2.get("strategies").is_none());
        assert_eq!(v2.arr("axes").unwrap().len(), 4);
    }
}
