//! One-call experiment runner: config -> engine + fleet + data + strategy
//! -> ExperimentResult. Shared by the CLI, examples, and all benches.
//!
//! [`Experiment::run`] executes with the config's observers (console log
//! when `verbose`, selection traces when `record_selections`);
//! [`Experiment::run_observed`] additionally attaches a caller-supplied
//! [`RoundObserver`] (progress bars, JSONL reporters, checkpointers, ...);
//! [`Experiment::run_from`] also takes a [`ResumeState`] (stored
//! checkpoint or warm start). [`resume_run`] is the whole fault-tolerance
//! path in one call: stored run id -> rebuilt experiment -> continued,
//! still-checkpointed execution, bitwise-identical to a run that was
//! never interrupted.

use crate::config::{ExperimentCfg, FleetSpec};
use crate::data::FedDataset;
use crate::fleet::{ChurnCfg, FleetInfo, LazyFleet};
use crate::fl::observer::{ConsoleObserver, NullObserver, ObserverSet, RoundObserver, SelectionTrace};
use crate::fl::server::{run_experiment_from, ExperimentResult, ResumeState, ServerCfg};
use crate::manifest::tests_support::chain_manifest;
use crate::manifest::Manifest;
use crate::runtime::{Engine, MockEngine};
use crate::sim::fleet::{build_fleet, fastest, slowest};
use crate::store::checkpoint::CheckpointObserver;
use crate::store::RunStore;
use crate::strategies::FleetCtx;
use crate::timing::{DeviceProfile, TimingCfg, TimingModel};

/// A fully wired experiment, reusable across strategies (the expensive
/// parts — engine compile, dataset — are built once).
pub struct Experiment {
    pub cfg: ExperimentCfg,
    pub engine: Box<dyn Engine>,
    pub fleet: Vec<DeviceProfile>,
    pub dataset: FedDataset,
    pub ctx: FleetCtx,
}

/// Parse "mock:<blocks>x<body>" model names.
fn mock_spec(model: &str) -> Option<(usize, usize)> {
    let rest = model.strip_prefix("mock:")?;
    let (b, s) = rest.split_once('x')?;
    Some((b.parse().ok()?, s.parse().ok()?))
}

fn build_engine(cfg: &ExperimentCfg) -> anyhow::Result<Box<dyn Engine>> {
    if let Some((blocks, body)) = mock_spec(&cfg.model) {
        let m = chain_manifest(blocks, body);
        return Ok(Box::new(MockEngine::new(m, cfg.seed)));
    }
    build_pjrt_engine(cfg)
}

#[cfg(feature = "pjrt")]
fn build_pjrt_engine(cfg: &ExperimentCfg) -> anyhow::Result<Box<dyn Engine>> {
    let dir = cfg.artifacts_dir.join(&cfg.model);
    Ok(Box::new(crate::runtime::PjrtEngine::open(&dir)?))
}

#[cfg(not(feature = "pjrt"))]
fn build_pjrt_engine(cfg: &ExperimentCfg) -> anyhow::Result<Box<dyn Engine>> {
    anyhow::bail!(
        "model {:?} needs the PJRT engine — rebuild with `--features pjrt` \
         (this build supports only mock:<blocks>x<body> models)",
        cfg.model
    )
}

impl Experiment {
    pub fn build(mut cfg: ExperimentCfg) -> anyhow::Result<Experiment> {
        let engine = build_engine(&cfg)?;
        let manifest: Manifest = engine.manifest().clone();

        // Trace-driven fleets: read the JSONL file ONCE and snapshot the
        // profiles into the config (and hence the run manifest), so resume
        // and reporting never depend on the external file again.
        if !cfg.fleet_trace.is_empty() && cfg.fleet_profiles.is_empty() {
            cfg.fleet_profiles =
                crate::fleet::trace::load_trace(std::path::Path::new(&cfg.fleet_trace))?;
        }

        // Three fleet shapes:
        // * trace profiles — eager devices + per-client links/windows;
        // * lazy generator — `fleet` holds one DeviceProfile PER TYPE and
        //   clients map onto types on demand (O(types), not O(n));
        // * classic specs — eager per-client devices, unchanged.
        let (fleet, fleet_info): (Vec<DeviceProfile>, FleetInfo) =
            if !cfg.fleet_profiles.is_empty() {
                let devices = cfg.fleet_profiles.iter().map(|p| p.device.clone()).collect();
                let links =
                    cfg.fleet_profiles.iter().map(|p| (p.up_mbps, p.down_mbps)).collect();
                let windows = cfg
                    .fleet_profiles
                    .iter()
                    .map(|p| (p.arrive_secs, p.depart_secs))
                    .collect();
                (devices, FleetInfo { lazy: None, links, windows })
            } else if let FleetSpec::Lazy { n, generator } = &cfg.fleet {
                let lf = LazyFleet::new(*n, generator.clone(), cfg.seed)?;
                let types = lf.device_types().to_vec();
                (types, FleetInfo { lazy: Some(lf), links: Vec::new(), windows: Vec::new() })
            } else {
                (build_fleet(&cfg.fleet, cfg.seed)?, FleetInfo::default())
            };
        anyhow::ensure!(!fleet.is_empty(), "empty fleet");

        // Calibrate the timing model so the slowest device's full round
        // matches the paper's wall-clock (DESIGN.md §4), then T_th =
        // factor x the FASTEST device's full-model round (Sec. 5.1).
        // For lazy fleets `fleet` is the device TYPE set; TimingModel is
        // linear in scale, so one model per type covers every client.
        let tcfg = if cfg.slowest_round_secs > 0.0 {
            TimingCfg::calibrated(
                &manifest,
                cfg.local_steps,
                slowest(&fleet)?.scale,
                cfg.slowest_round_secs,
            )
        } else {
            TimingCfg::default()
        };
        let timings: Vec<TimingModel> = fleet
            .iter()
            .map(|d| TimingModel::profile(&manifest, d, &tcfg))
            .collect();
        let fast_tm = TimingModel::profile(&manifest, fastest(&fleet)?, &tcfg);
        let t_th = cfg.t_th_factor * fast_tm.full_round_time(&manifest, cfg.local_steps);

        let n_clients = match &fleet_info.lazy {
            Some(lf) => lf.n,
            None => fleet.len(),
        };
        let dataset = if fleet_info.lazy.is_some() {
            FedDataset::build_lazy(&manifest, n_clients, cfg.alpha, cfg.eval_batches, cfg.seed)
        } else {
            FedDataset::build(&manifest, n_clients, cfg.alpha, cfg.eval_batches, cfg.seed)
        };
        let ctx = FleetCtx {
            manifest,
            timings,
            t_th,
            local_steps: cfg.local_steps,
            lr: cfg.lr,
            fleet: fleet_info,
        };
        Ok(Experiment { cfg, engine, fleet, dataset, ctx })
    }

    /// Run one strategy (cfg.strategy unless overridden).
    pub fn run(&mut self, strategy_override: Option<&str>) -> anyhow::Result<ExperimentResult> {
        self.run_observed(strategy_override, &mut NullObserver)
    }

    /// Run one strategy with an extra caller-supplied observer on top of
    /// the config-driven ones (console log, selection trace).
    pub fn run_observed(
        &mut self,
        strategy_override: Option<&str>,
        extra: &mut dyn RoundObserver,
    ) -> anyhow::Result<ExperimentResult> {
        self.run_from(strategy_override, extra, None)
    }

    /// Run one strategy, optionally continuing from a [`ResumeState`]
    /// (checkpoint resume or warm start). Selection traces, when enabled,
    /// cover only the rounds executed by this call — traces are not part
    /// of checkpoints.
    pub fn run_from(
        &mut self,
        strategy_override: Option<&str>,
        extra: &mut dyn RoundObserver,
        resume: Option<ResumeState>,
    ) -> anyhow::Result<ExperimentResult> {
        let name = strategy_override.unwrap_or(&self.cfg.strategy).to_string();
        // Built through the registry so the config's parameter bag
        // (`--set strategy.<s>.<p>=v`, swept axes, the deprecated --beta
        // alias) reaches the builder.
        let mut strategy = crate::strategies::registry::builtin().build(
            &name,
            &self.ctx,
            self.cfg.seed,
            &self.cfg.strategy_params,
        )?;
        let churn = ChurnCfg {
            dropout: self.cfg.churn_dropout,
            period_secs: self.cfg.churn_period_secs,
            avail_frac: self.cfg.churn_avail_frac,
        };
        let server_cfg = ServerCfg {
            rounds: self.cfg.rounds,
            eval_every: self.cfg.eval_every,
            comm: self.cfg.comm_model(),
            exec_threads: self.cfg.exec_threads,
            halt_after: self.cfg.halt_after,
            sample: self.cfg.fleet_sample,
            seed: self.cfg.seed,
            churn: churn.active().then_some(churn),
            speculate_depth: self.cfg.exec_speculate_depth,
        };
        let mut console = self.cfg.verbose.then(|| ConsoleObserver::new(&name));
        let mut trace = self.cfg.record_selections.then(SelectionTrace::default);
        let mut observers = ObserverSet::new();
        if let Some(c) = console.as_mut() {
            observers.push(c);
        }
        if let Some(t) = trace.as_mut() {
            observers.push(t);
        }
        observers.push(extra);
        let mut res = run_experiment_from(
            self.engine.as_ref(),
            &self.dataset,
            strategy.as_mut(),
            &self.ctx,
            &server_cfg,
            &mut observers,
            resume,
        )?;
        drop(observers);
        if let Some(t) = trace {
            res.selections = t.into_inner();
        }
        Ok(res)
    }
}

/// Resume an interrupted stored run to completion: rebuild the experiment
/// from the manifest's config snapshot, restore global parameters + policy
/// state (+ strategy RNG) from the latest checkpoint, and continue the
/// round loop — checkpointing every `every` rounds into the same run. The
/// result is bitwise-identical to a run that was never interrupted
/// (`tests/resume.rs`).
pub fn resume_run(
    store: &RunStore,
    id: &str,
    every: usize,
    extra: &mut dyn RoundObserver,
) -> anyhow::Result<ExperimentResult> {
    resume_run_until(store, id, every, None, extra)
}

/// [`resume_run`], but halting again after absolute round `halt_after`
/// (when `Some` and before the final round). This is the campaign
/// operator's segmented-execution primitive: a worker advances a cell one
/// checkpoint-aligned segment at a time, so successive-halving rungs can
/// be judged at shared boundaries and leases stay short-lived. Passing
/// `None` (or a boundary at/past the configured rounds) runs to
/// completion — the config snapshot in the manifest is never altered, so
/// the stored run stays bitwise-identical to an uninterrupted one.
pub fn resume_run_until(
    store: &RunStore,
    id: &str,
    every: usize,
    halt_after: Option<usize>,
    extra: &mut dyn RoundObserver,
) -> anyhow::Result<ExperimentResult> {
    let mut manifest = store.load_manifest(id)?;
    let resume = crate::store::checkpoint::resume_state(store, &manifest)?;
    // Anything recorded past the checkpoint will be recomputed (and, by
    // the determinism invariant, recomputed identically).
    manifest.records.truncate(resume.completed);
    let name = manifest.strategy.clone();
    let mut exp = Experiment::build(manifest.config.clone())?;
    // The halt is an execution-session concern, not part of the run's
    // identity: it lives on the rebuilt experiment only.
    exp.cfg.halt_after = halt_after.filter(|&h| h < exp.cfg.rounds);
    let mut ckpt = CheckpointObserver::resume(store, manifest, every);
    let res = {
        let mut set = ObserverSet::new();
        set.push(&mut ckpt);
        set.push(extra);
        exp.run_from(Some(&name), &mut set, Some(resume))?
    };
    if let Some(e) = ckpt.take_error() {
        anyhow::bail!("run {id} resumed, but persisting its state failed: {e}");
    }
    Ok(res)
}

/// Convenience: build + run in one call.
pub fn run_one(cfg: ExperimentCfg) -> anyhow::Result<ExperimentResult> {
    Experiment::build(cfg)?.run(None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FleetSpec;

    fn mock_cfg() -> ExperimentCfg {
        ExperimentCfg {
            model: "mock:6x50".into(),
            strategy: "fedel".into(),
            fleet: FleetSpec::Scales(vec![1.0, 2.0, 4.0]),
            rounds: 8,
            local_steps: 4,
            lr: 0.3,
            eval_every: 2,
            eval_batches: 2,
            slowest_round_secs: 3600.0,
            verbose: false,
            ..Default::default()
        }
    }

    #[test]
    fn mock_experiment_end_to_end() {
        let res = run_one(mock_cfg()).unwrap();
        assert_eq!(res.records.len(), 8);
        assert!(res.sim_total_secs > 0.0);
        assert!(res.final_acc > 0.0);
        assert_eq!(res.final_params.len(), 6 * 50 + 6 * 4);
        // eval accuracy should improve from the first eval to the final
        // (train losses aren't comparable across FedEL's changing exits)
        let curve = res.acc_curve();
        assert!(curve.len() >= 2);
        assert!(
            res.final_acc > curve[0].1,
            "{} -> {}",
            curve[0].1,
            res.final_acc
        );
    }

    #[test]
    fn fedel_rounds_are_cheaper_than_fedavg() {
        let mut cfg = mock_cfg();
        cfg.strategy = "fedavg".into();
        let avg = run_one(cfg.clone()).unwrap();
        cfg.strategy = "fedel".into();
        let fedel = run_one(cfg).unwrap();
        let avg_round = avg.records[0].round_secs;
        let fedel_round = fedel.records[0].round_secs;
        assert!(
            fedel_round < avg_round * 0.6,
            "fedel {fedel_round} vs fedavg {avg_round}"
        );
    }

    #[test]
    fn calibration_pins_slowest_round() {
        let cfg = mock_cfg();
        let exp = Experiment::build(cfg).unwrap();
        // slowest = scale 4.0 (client 2)
        let t = exp.ctx.full_round_time(2);
        assert!((t - 3600.0).abs() / 3600.0 < 0.02, "{t}");
    }

    #[test]
    fn every_strategy_runs_on_mock() {
        for name in crate::strategies::table1_names() {
            let mut cfg = mock_cfg();
            cfg.strategy = name.into();
            cfg.rounds = 3;
            let res = run_one(cfg).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(res.strategy, name);
        }
    }

    #[test]
    fn non_mock_model_errors_without_pjrt_feature() {
        #[cfg(not(feature = "pjrt"))]
        {
            let mut cfg = mock_cfg();
            cfg.model = "definitely_missing_model".into();
            let err = Experiment::build(cfg).unwrap_err().to_string();
            assert!(err.contains("pjrt"), "{err}");
        }
    }
}
