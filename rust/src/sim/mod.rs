//! Experiment wiring: fleet construction, the one-call experiment runner
//! used by the CLI, the examples, and every bench, and the campaign
//! runner for whole strategy × seed × fleet × T_th grids.

pub mod campaign;
pub mod experiment;
pub mod fleet;

pub use campaign::{run_campaign, CampaignCfg};
pub use experiment::{run_one, Experiment};
pub use fleet::build_fleet;
