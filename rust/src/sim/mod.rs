//! Experiment wiring: fleet construction + the one-call experiment runner
//! used by the CLI, the examples, and every bench.

pub mod experiment;
pub mod fleet;

pub use experiment::{run_one, Experiment};
pub use fleet::build_fleet;
