//! Table 3 (Appendix B.4) — FedEL composed with non-IID-aware aggregation:
//! FedProx and FedNova with and without FedEL on the CIFAR10-like
//! 10-device workload.

use fedel::report::bench::{banner, rounds, Workload};
use fedel::report::Table;
use fedel::sim::experiment::Experiment;

fn main() -> anyhow::Result<()> {
    banner("Table 3", "FedProx/FedNova +- FedEL (CIFAR10-like, 10 dev)");
    let mut cfg = Workload::Cifar10Dev.cfg(42);
    cfg.rounds = rounds(20, 120);
    let mut exp = Experiment::build(cfg)?;

    let mut t = Table::new(
        "measured vs paper",
        &["Method", "Acc", "Time", "Speedup", "paper:Acc", "paper:Time", "paper:Speedup"],
    );
    let paper = [
        ("fedprox", "56.1%", "82.3h", "N/A"),
        ("fedprox+fedel", "56.6%", "45.4h", "1.81x"),
        ("fednova", "66.3%", "84.7h", "N/A"),
        ("fednova+fedel", "66.1%", "47.8h", "1.77x"),
    ];
    let mut base_time = 0.0;
    for (name, p_acc, p_time, p_sp) in paper {
        let res = exp.run(Some(name))?;
        let target = 0.95 * res.final_acc;
        let time = res.time_to_accuracy(target).unwrap_or(res.sim_total_secs);
        let speedup = if name.contains('+') {
            format!("{:.2}x", base_time / time.max(1e-9))
        } else {
            base_time = time;
            "N/A".into()
        };
        t.row(vec![
            name.to_string(),
            format!("{:.2}%", 100.0 * res.final_acc),
            fedel::util::fmt_hours(time),
            speedup,
            p_acc.to_string(),
            p_time.to_string(),
            p_sp.to_string(),
        ]);
    }
    t.print();
    println!("shape: +FedEL keeps accuracy within ~1% while cutting time ~1.8x");
    Ok(())
}
