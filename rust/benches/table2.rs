//! Table 2 (Appendix B.3) — deviation of FedEL's per-round training time
//! from the target T_th, per workload, plus the FedAvg round time and the
//! resulting speedup.

use fedel::report::bench::{banner, Workload};
use fedel::report::Table;
use fedel::sim::experiment::Experiment;
use fedel::util::stats::mean;

fn main() -> anyhow::Result<()> {
    banner("Table 2", "per-round time deviation from T_th");
    let mut t = Table::new(
        "measured vs paper",
        &["Workload", "FedEL(min)", "T_th(min)", "Diff", "FedAvg(min)", "Speedup",
          "paper:FedEL", "paper:T_th", "paper:Diff"],
    );
    let paper = [
        (Workload::Cifar10Dev, 38.2, 36.0, "6.1%"),
        (Workload::TinyIn100Dev, 45.1, 42.2, "6.8%"),
        (Workload::Speech100Dev, 54.9, 53.2, "3.2%"),
        (Workload::Reddit100Dev, 48.6, 40.9, "18.9%"),
    ];
    for (w, p_fedel, p_tth, p_diff) in paper {
        let mut exp = Experiment::build(w.cfg(42))?;
        let fedel = exp.run(Some("fedel"))?;
        let fedavg = exp.run(Some("fedavg"))?;
        let fedel_mins: Vec<f64> = fedel
            .records
            .iter()
            .map(|r| (r.round_secs - 30.0) / 60.0) // strip comm constant
            .collect();
        let avg_round = mean(
            &fedavg.records.iter().map(|r| (r.round_secs - 30.0) / 60.0).collect::<Vec<_>>(),
        );
        let t_th_min = exp.ctx.t_th / 60.0;
        let fedel_round = mean(&fedel_mins);
        let diff = 100.0 * (fedel_round - t_th_min) / t_th_min;
        t.row(vec![
            w.model().to_string(),
            format!("{fedel_round:.1}"),
            format!("{t_th_min:.1}"),
            format!("{diff:+.1}%"),
            format!("{avg_round:.1}"),
            format!("{:.2}x", avg_round / fedel_round),
            format!("{p_fedel:.1}"),
            format!("{p_tth:.1}"),
            p_diff.to_string(),
        ]);
    }
    t.print();
    println!("paper: deviations 3.2-6.8% for CNNs, 18.9% for the LM; speedups 1.87-3.87x");
    Ok(())
}
