//! Table 1 — time-to-accuracy of FedEL vs all baselines on the paper's
//! four workloads. Prints paper rows next to measured rows; absolute
//! numbers differ (synthetic data, scaled models) but the *shape* —
//! who wins, accuracy ordering, speedup band — is the claim under test.

use fedel::report::bench::{banner, paper_table1, Workload};
use fedel::report::{render_table1, table1_rows, Table};
use fedel::sim::experiment::Experiment;
use fedel::strategies::table1_names;

fn main() -> anyhow::Result<()> {
    banner("Table 1", "time-to-accuracy, 8 methods x 4 workloads");
    let only: Option<String> = std::env::var("FEDEL_TABLE1_WORKLOAD").ok();

    for w in Workload::all() {
        if let Some(f) = &only {
            if !w.label().to_lowercase().contains(&f.to_lowercase()) {
                continue;
            }
        }
        println!("---- {} ----", w.label());
        let mut paper = Table::new("paper (Table 1)", &["Method", "Metric", "Time", "Speedup"]);
        for (m, metric, hours, sp) in paper_table1(w) {
            paper.row(vec![
                m.to_string(),
                format!("{metric:.2}"),
                format!("{hours:.1}h"),
                sp.to_string(),
            ]);
        }
        paper.print();

        let mut exp = Experiment::build(w.cfg(42))?;
        let mut results = Vec::new();
        for name in table1_names() {
            let t0 = std::time::Instant::now();
            let res = exp.run(Some(name))?;
            eprintln!(
                "  [{name}] final_acc={:.3} ppl={:.2} sim={:.1}h wall={:.1}s",
                res.final_acc,
                res.final_perplexity(),
                res.sim_total_secs / 3600.0,
                t0.elapsed().as_secs_f64()
            );
            results.push(res);
        }
        let rows = table1_rows(&results, 0.95, w.is_lm());
        render_table1("measured (this repo)", &rows, w.is_lm()).print();

        // Shape summary, reported not asserted (benches must not panic).
        let fedavg = &rows[0];
        let fedel = rows.iter().find(|r| r.method == "fedel").unwrap();
        let sp = fedel.speedup_vs_fedavg.unwrap_or(1.0);
        println!(
            "shape: fedel speedup {sp:.2}x (paper band 1.87-3.87), \
             fedel acc {:.3} vs fedavg {:.3} (paper: on par or better)\n",
            fedel.final_acc, fedavg.final_acc
        );
    }
    Ok(())
}
