//! Figure 5 — tensor importance across FL clients vs centralized training.
//! Non-iid clients disagree with each other and with the centralized
//! importance profile; that disagreement is Limitation #2's driver.

use fedel::elastic::importance::local_importance;
use fedel::report::bench::{banner, Workload};
use fedel::report::Table;
use fedel::runtime::{Engine, TrainSession};
use fedel::sim::experiment::Experiment;

/// Cosine similarity of two importance vectors.
fn cosine(a: &[f64], b: &[f64]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|x| x * x).sum::<f64>().sqrt();
    dot / (na * nb).max(1e-12)
}

fn main() -> anyhow::Result<()> {
    banner("Figure 5", "tensor importance: FL clients vs centralized");
    let mut cfg = Workload::Cifar10Dev.cfg(42);
    cfg.rounds = 1;
    let exp = Experiment::build(cfg)?;
    let m = exp.engine.manifest().clone();
    let params = m.load_init()?;
    let mask = vec![1.0f32; m.param_count];
    let nb = m.num_blocks;

    // Per-client importance from one full-model probe step each, through
    // one engine session.
    let mut session = exp.engine.session();
    let mut client_imps: Vec<Vec<f64>> = Vec::new();
    for c in 0..exp.dataset.clients.len() {
        let (x, y) = exp.dataset.clients[c].sample_batch(&exp.dataset.spec, &m, 0);
        let out = session.train_step(nb, &params, &x, &y, &mask, 0.05)?;
        client_imps.push(local_importance(&out.sq_grads, 0.05));
    }
    // "Centralized" importance: probe on the iid test distribution.
    let (x, y) = exp.dataset.test_batches[0].clone();
    let central = local_importance(
        &session.train_step(nb, &params, &x, &y, &mask, 0.05)?.sq_grads,
        0.05,
    );

    let mut t = Table::new(
        "importance agreement (cosine similarity)",
        &["pair", "cosine"],
    );
    let mut cross = Vec::new();
    for i in 0..client_imps.len() {
        cross.push(cosine(&client_imps[i], &central));
    }
    t.row(vec![
        "mean(client, centralized)".into(),
        format!("{:.4}", fedel::util::stats::mean(&cross)),
    ]);
    let mut pairwise = Vec::new();
    for i in 0..client_imps.len() {
        for j in (i + 1)..client_imps.len() {
            pairwise.push(cosine(&client_imps[i], &client_imps[j]));
        }
    }
    t.row(vec![
        "mean(client, client)".into(),
        format!("{:.4}", fedel::util::stats::mean(&pairwise)),
    ]);
    t.print();

    // Per-tensor table for the first few tensors (the figure's x-axis).
    let mut pt = Table::new(
        "per-tensor importance (normalized)",
        &["tensor", "client0", "client5", "centralized"],
    );
    let norm = |v: &[f64]| -> Vec<f64> {
        let s: f64 = v.iter().sum();
        v.iter().map(|x| x / s.max(1e-12)).collect()
    };
    let (c0, c5, ce) = (norm(&client_imps[0]), norm(&client_imps[5]), norm(&central));
    for i in 0..m.tensors.len().min(16) {
        pt.row(vec![
            m.tensors[i].name.clone(),
            format!("{:.4}", c0[i]),
            format!("{:.4}", c5[i]),
            format!("{:.4}", ce[i]),
        ]);
    }
    pt.print();
    println!(
        "shape (paper Fig 5): clients disagree with centralized importance under \
         Dirichlet(0.1) non-iid data — cross-client cosine < 1 indicates drift pressure"
    );
    Ok(())
}
