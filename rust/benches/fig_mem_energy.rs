//! Figures 8 + 9 — training memory overhead, power, and energy per method.
//! Memory comes from the analytic model over each strategy's actual round
//! plans; energy from device power x simulated active time (DESIGN.md §4).

use fedel::metrics::energy::energy_report;
use fedel::metrics::memory::memory_bytes;
use fedel::report::bench::{banner, rounds, Workload};
use fedel::report::Table;
use fedel::runtime::Engine;
use fedel::sim::experiment::Experiment;
use fedel::strategies::{by_name, table1_names, Strategy};
use fedel::util::stats::mean;

fn main() -> anyhow::Result<()> {
    banner("Figures 8+9", "memory overhead, power, energy per method");
    let mut cfg = Workload::Cifar10Dev.cfg(42);
    cfg.rounds = rounds(10, 80);
    let mut exp = Experiment::build(cfg)?;

    let mut t = Table::new(
        "measured",
        &["Method", "Mem(MB)", "MemVsFedAvg", "Power(W)", "Energy(kJ)", "EnergyVsFedAvg"],
    );
    let mut fedavg_mem = 0.0;
    let mut fedavg_kj = 0.0;
    for name in table1_names() {
        // Memory: average the analytic model over the strategy's first
        // round of plans (mask + exit determine the footprint).
        let mut strat = by_name(name, &exp.ctx, 0.6, exp.cfg.seed)?;
        let global = exp.engine.manifest().load_init()?;
        let plans = strat.plan_round(0, &exp.ctx, &global);
        let m = exp.engine.manifest().clone();
        let mems: Vec<f64> = plans
            .iter()
            .map(|p| memory_bytes(&m, p.exit, &p.mask.tensor_coverage()).total_mb())
            .collect();
        let mem = mean(&mems);

        // Energy: full experiment run.
        let res = exp.run(Some(name))?;
        let er = energy_report(&res, &exp.fleet)?;

        if name == "fedavg" {
            fedavg_mem = mem;
            fedavg_kj = er.total_kj;
        }
        t.row(vec![
            name.to_string(),
            format!("{mem:.1}"),
            format!("{:+.1}%", 100.0 * (mem - fedavg_mem) / fedavg_mem),
            format!("{:.1}", er.mean_power_w),
            format!("{:.0}", er.total_kj),
            format!("{:+.1}%", 100.0 * (er.total_kj - fedavg_kj) / fedavg_kj),
        ]);
    }
    t.print();
    println!(
        "paper: FedEL cuts memory up to 32.7% vs FedAvg (Fig 8); power is \
         ~method-independent while FedEL cuts total energy ~49.6% (Fig 9)"
    );
    Ok(())
}
