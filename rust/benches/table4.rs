//! Table 4 (Appendix B.6) — the O₁ convergence-bias term with and without
//! window rollback. Rollback (resetting the window to the initial window
//! when the front reaches the model end) should LOWER the mean O₁.

use fedel::report::bench::{banner, rounds, Workload};
use fedel::report::Table;
use fedel::sim::experiment::Experiment;

fn main() -> anyhow::Result<()> {
    banner("Table 4", "O1 bias: rollback vs no-rollback");
    let mut cfg = Workload::Cifar10Dev.cfg(42);
    cfg.rounds = rounds(30, 150);
    let mut exp = Experiment::build(cfg)?;

    let roll = exp.run(Some("fedel"))?;
    let noroll = exp.run(Some("fedel-norollback"))?;

    let mut t = Table::new(
        "measured vs paper",
        &["Method", "O1 mean", "O1 std", "paper:mean", "paper:std"],
    );
    t.row(vec![
        "Rollback".into(),
        format!("{:.2}", roll.mean_o1()),
        format!("{:.2}", roll.std_o1()),
        "63.06".into(),
        "8.62".into(),
    ]);
    t.row(vec![
        "Not Rollback".into(),
        format!("{:.2}", noroll.mean_o1()),
        format!("{:.2}", noroll.std_o1()),
        "78.18".into(),
        "2.62".into(),
    ]);
    t.print();
    println!(
        "shape: rollback mean O1 {} no-rollback ({:.2} vs {:.2}); paper: rollback lower",
        if roll.mean_o1() < noroll.mean_o1() { "<" } else { ">= (!)" },
        roll.mean_o1(),
        noroll.mean_o1()
    );
    Ok(())
}
