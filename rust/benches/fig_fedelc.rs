//! Figures 13 + 17 — FedEL vs FedEL-C vs FedAvg time-to-accuracy. FedEL-C
//! collapses the end edge to the previous front (disjoint windows, no
//! overlap between consecutive windows) and loses accuracy.

use fedel::report::bench::{banner, rounds, Workload};
use fedel::report::Table;
use fedel::sim::experiment::Experiment;

fn main() -> anyhow::Result<()> {
    banner("Figures 13/17", "FedEL vs FedEL-C vs FedAvg");
    for w in [Workload::Cifar10Dev, Workload::TinyIn100Dev, Workload::Speech100Dev] {
        let mut cfg = w.cfg(42);
        cfg.rounds = rounds(15, 100);
        println!("---- {} ----", w.label());
        let mut exp = Experiment::build(cfg)?;
        let mut t = Table::new(
            "time-to-accuracy",
            &["method", "final_acc", "sim_total_h"],
        );
        let mut accs = Vec::new();
        for name in ["fedavg", "fedel-c", "fedel"] {
            let res = exp.run(Some(name))?;
            accs.push((name, res.final_acc));
            t.row(vec![
                name.into(),
                format!("{:.3}", res.final_acc),
                format!("{:.1}", res.sim_total_secs / 3600.0),
            ]);
        }
        t.print();
        let get = |n: &str| accs.iter().find(|(m, _)| *m == n).unwrap().1;
        println!(
            "shape: fedel {:.3} vs fedel-c {:.3} (paper: FedEL-C lower — windows \
             must overlap/adjust between rounds)\n",
            get("fedel"),
            get("fedel-c")
        );
    }
    Ok(())
}
