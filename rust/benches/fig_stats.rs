//! Figure 21 (Appendix B.5) — statistical comparison: box plots of final
//! accuracy over independent seeds, with 95% CIs and a Welch t-test of
//! FedEL against each baseline.

use fedel::report::bench::{banner, rounds, Workload};
use fedel::report::Table;
use fedel::sim::experiment::Experiment;
use fedel::util::stats::{box_stats, ci95_half_width, mean, welch_t};

fn main() -> anyhow::Result<()> {
    banner("Figure 21", "accuracy distributions over seeds (box stats + CI)");
    let seeds: Vec<u64> = if fedel::report::bench::full_scale() {
        vec![1, 2, 3, 4, 5]
    } else {
        vec![1, 2, 3]
    };
    let methods = ["fedavg", "elastictrainer", "timelyfl", "fedel"];
    let mut cfg = Workload::Cifar10Dev.cfg(0);
    cfg.rounds = rounds(12, 80);

    let mut accs: Vec<(&str, Vec<f64>)> = methods.iter().map(|&m| (m, Vec::new())).collect();
    for &seed in &seeds {
        let mut cfg_s = cfg.clone();
        cfg_s.seed = seed;
        let mut exp = Experiment::build(cfg_s)?;
        for (name, v) in &mut accs {
            let res = exp.run(Some(name))?;
            v.push(res.final_acc);
        }
    }

    let mut t = Table::new(
        "final accuracy over seeds",
        &["method", "mean", "ci95", "min", "q1", "median", "q3", "max"],
    );
    for (name, v) in &accs {
        let b = box_stats(v);
        t.row(vec![
            name.to_string(),
            format!("{:.3}", mean(v)),
            format!("±{:.3}", ci95_half_width(v)),
            format!("{:.3}", b.min),
            format!("{:.3}", b.q1),
            format!("{:.3}", b.median),
            format!("{:.3}", b.q3),
            format!("{:.3}", b.max),
        ]);
    }
    t.print();

    let fedel = &accs.last().unwrap().1;
    let mut s = Table::new("Welch t vs fedel", &["baseline", "t"]);
    for (name, v) in &accs[..accs.len() - 1] {
        s.row(vec![name.to_string(), format!("{:.2}", welch_t(fedel, v))]);
    }
    s.print();
    println!("paper shape: FedEL maintains or exceeds baselines with non-overlapping CIs vs elastic/timely");
    Ok(())
}
