//! Micro-benchmarks of the L3 hot paths (criterion-style medians; the
//! criterion crate is unavailable offline — see util::prop / report::bench
//! for the in-repo substrates). These feed EXPERIMENTS.md §Perf.

use fedel::config::{ExperimentCfg, FleetSpec};
use fedel::elastic::{select, SelectorInput};
use fedel::fl::aggregate::{AggregateRule, MaskedAggregator};
use fedel::manifest::tests_support::chain_manifest;
use fedel::report::bench::{banner, time_median};
use fedel::report::Table;
use fedel::sim::experiment::Experiment;
use fedel::timing::{DeviceProfile, TimingCfg, TimingModel};
use fedel::window::{BlockCosts, WindowPolicy, WindowState};

fn main() -> anyhow::Result<()> {
    banner("perf_hotpaths", "L3 micro-benchmarks (median wall time)");
    let mut t = Table::new("hot paths", &["path", "median", "throughput"]);

    // --- DP selector on a large window ---------------------------------
    let m = chain_manifest(64, 100);
    let tm = TimingModel::profile(&m, &DeviceProfile::orin(), &TimingCfg::default());
    let order: Vec<usize> = (0..64).rev().map(|b| 2 * b).collect();
    let imp: Vec<f64> = (0..64).map(|i| 1.0 + (i % 7) as f64).collect();
    let budget = tm.full_backward_time() * 0.4;
    let d = time_median(21, || {
        let sel = select(&SelectorInput { order: &order, importance: &imp, budget, timing: &tm });
        std::hint::black_box(sel);
    });
    t.row(vec![
        "DP select (64 tensors, 2048 buckets)".into(),
        format!("{:.1}us", d.as_secs_f64() * 1e6),
        String::new(),
    ]);

    // --- masked aggregation over a 100-client x 400k-param fleet --------
    let p = 400_640usize;
    let params = vec![0.5f32; p];
    let mask = vec![1.0f32; p];
    let global = vec![0.0f32; p];
    let d = time_median(9, || {
        let mut agg = MaskedAggregator::new(p, AggregateRule::Masked);
        for _ in 0..20 {
            agg.add(&params, &mask, 1.0, 4, &global).unwrap();
        }
        std::hint::black_box(agg.finish(&global));
    });
    let gbps = (20.0 * p as f64 * 8.0) / d.as_secs_f64() / 1e9;
    t.row(vec![
        "masked aggregate (20 adds x 400k params)".into(),
        format!("{:.2}ms", d.as_secs_f64() * 1e3),
        format!("{gbps:.1} GB/s"),
    ]);

    // --- sparse vs dense masked aggregation -----------------------------
    sparse_aggregate_bench(&mut t);

    // --- mask expansion --------------------------------------------------
    let tensor_mask = vec![1.0f32; m.tensors.len()];
    let d = time_median(21, || {
        std::hint::black_box(m.expand_mask(&tensor_mask));
    });
    t.row(vec![
        format!("expand_mask ({} params)", m.param_count),
        format!("{:.1}us", d.as_secs_f64() * 1e6),
        String::new(),
    ]);

    // --- sliding-window walk: cached vs recomputed forward prefix -------
    // BlockCosts now precomputes the forward prefix sums once; before,
    // initial_window/front_advance re-summed fwd[..front] at every
    // candidate front — O(nb^2) per client per round. The naive walk
    // below hand-rolls that old arithmetic for comparison.
    let nb = 512;
    let rounds = 256;
    let train: Vec<f64> = (0..nb).map(|b| 1.0 + (b % 5) as f64 * 0.25).collect();
    let fwd: Vec<f64> = (0..nb).map(|b| 0.1 + (b % 3) as f64 * 0.05).collect();
    let costs = BlockCosts::new(train.clone(), fwd.clone());
    let t_th = 64.0;
    let sel = vec![true; nb];
    let d_cached = time_median(15, || {
        let mut st = WindowState::new(&costs, t_th, WindowPolicy::FedEl);
        for _ in 0..rounds {
            st.advance(&costs, t_th, &sel);
        }
        std::hint::black_box(st.win);
    });
    let d_naive = time_median(15, || {
        std::hint::black_box(naive_window_walk(&train, &fwd, t_th, rounds));
    });
    let win_speedup = d_naive.as_secs_f64() / d_cached.as_secs_f64().max(1e-12);
    t.row(vec![
        format!("window walk ({nb} blocks x {rounds} rounds), cached prefix"),
        format!("{:.1}us", d_cached.as_secs_f64() * 1e6),
        String::new(),
    ]);
    t.row(vec![
        format!("window walk ({nb} blocks x {rounds} rounds), naive prefix"),
        format!("{:.1}us", d_naive.as_secs_f64() * 1e6),
        format!("{win_speedup:.1}x win"),
    ]);
    println!(
        "window walk [{nb} blocks x {rounds} rounds]: cached {:.1}us, naive {:.1}us -> {win_speedup:.1}x",
        d_cached.as_secs_f64() * 1e6,
        d_naive.as_secs_f64() * 1e6,
    );

    // --- async event queue: binary heap vs linear scan ------------------
    event_queue_bench(&mut t);

    // --- round throughput: sequential vs parallel client fan-out --------
    // 32-client fedavg rounds on the mock engine; the only difference
    // between the two runs is exec_threads (1 vs one-per-core). Results
    // are bitwise identical — this measures pure host wall-clock.
    round_throughput(&mut t, "mock:8x100", 32, 32)?;
    round_throughput(&mut t, "mock:8x20000", 32, 4)?;

    // --- speculative async dispatch vs serial event loop ----------------
    speculative_async_bench(&mut t)?;

    pjrt_benches(&mut t)?;

    t.print();
    Ok(())
}

/// Run-encoded sparse adds ([`fedel::fl::sparse::SparseDelta`]) against
/// the dense full-vector walk, at 10% and 100% mask coverage. Three
/// claims, the first two asserted as tripwires:
/// * bitwise: both paths finish to identical globals;
/// * aggregation cost scales with the *masked* size — at 10% coverage the
///   sparse path must win clearly (the dense walk still touches all 400k
///   elements to add weighted zeros);
/// * at full coverage the sparse path degenerates to one dense run and
///   stays within noise of the dense walk.
fn sparse_aggregate_bench(t: &mut Table) {
    use fedel::fl::sparse::SparseDelta;
    let p = 400_640usize;
    let global = vec![0.0f32; p];
    for coverage in [0.1f64, 1.0] {
        let covered = (p as f64 * coverage) as usize;
        let mut mask = vec![0.0f32; p];
        mask[..covered].fill(1.0);
        // off-mask elements sit at the dispatched global (engine contract)
        let params: Vec<f32> =
            (0..p).map(|k| if k < covered { 0.5 } else { global[k] }).collect();
        let delta = SparseDelta::from_dense_mask(&mask, &params);

        let mut dense_out = Vec::new();
        let d_dense = time_median(9, || {
            let mut agg = MaskedAggregator::new(p, AggregateRule::Masked);
            for _ in 0..20 {
                agg.add(&params, &mask, 1.0, 4, &global).unwrap();
            }
            dense_out = std::hint::black_box(agg.finish(&global));
        });
        let mut sparse_out = Vec::new();
        let d_sparse = time_median(9, || {
            let mut agg = MaskedAggregator::new(p, AggregateRule::Masked);
            for _ in 0..20 {
                agg.add_sparse(&delta, 1.0, 4, &global).unwrap();
            }
            sparse_out = std::hint::black_box(agg.finish(&global));
        });
        assert_eq!(dense_out.len(), sparse_out.len());
        assert!(
            dense_out.iter().zip(&sparse_out).all(|(a, b)| a.to_bits() == b.to_bits()),
            "sparse aggregation diverged from dense at {coverage} coverage"
        );

        let speedup = d_dense.as_secs_f64() / d_sparse.as_secs_f64().max(1e-12);
        let pct = (coverage * 100.0) as usize;
        t.row(vec![
            format!("masked aggregate, dense add ({pct}% coverage)"),
            format!("{:.2}ms", d_dense.as_secs_f64() * 1e3),
            String::new(),
        ]);
        t.row(vec![
            format!("masked aggregate, sparse add ({pct}% coverage)"),
            format!("{:.2}ms", d_sparse.as_secs_f64() * 1e3),
            format!("{speedup:.1}x win"),
        ]);
        println!(
            "sparse aggregate [{pct}% of {p} params x 20 adds]: dense {:.2}ms, sparse {:.2}ms -> {speedup:.1}x",
            d_dense.as_secs_f64() * 1e3,
            d_sparse.as_secs_f64() * 1e3,
        );
        if coverage < 0.5 {
            assert!(
                speedup >= 2.0,
                "sparse add should clearly beat the dense walk at {pct}% coverage, got {speedup:.2}x"
            );
        } else {
            assert!(
                speedup >= 1.0 / 3.0,
                "sparse add should stay within noise of dense at full coverage, got {speedup:.2}x"
            );
        }
    }
}

/// The pre-prefix-sum window walk: FedEl policy with every block selected
/// (front advance + rollback), recomputing the forward prefix by
/// summation at every candidate front exactly as the old
/// `BlockCosts::fwd_prefix` did.
fn naive_window_walk(train: &[f64], fwd: &[f64], t_th: f64, rounds: usize) -> (usize, usize) {
    let nb = train.len();
    let fwd_prefix = |front: usize| -> f64 { fwd[..front].iter().sum() };
    let initial = || {
        let mut acc = 0.0;
        for b in 0..nb {
            acc += train[b];
            if acc + fwd_prefix(b + 1) >= t_th {
                return b + 1;
            }
        }
        nb
    };
    let advance = |from: usize| {
        let mut acc = 0.0;
        let mut front = from;
        while front < nb {
            acc += train[front];
            front += 1;
            if acc + fwd_prefix(front) >= t_th {
                break;
            }
        }
        front.max(from + 1).min(nb)
    };
    let mut front = initial();
    let mut resets = 0usize;
    for _ in 0..rounds {
        if front >= nb {
            front = initial();
            resets += 1;
        } else {
            front = advance(front);
        }
    }
    (front, resets)
}

/// The async executor's next-event lookup at fleet scale: the shipped
/// binary heap (`fl::exec::event`, O(log n) per event, keyed by
/// (finish, slot) exactly like `EventKey`) against the pre-PR linear
/// min-scan (O(n) per event). Both replay the same synthetic
/// dispatch/complete trace over 100k in-flight slots and must pop the
/// identical slot sequence — the heap is a speedup, not a reordering.
fn event_queue_bench(t: &mut Table) {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    const SLOTS: usize = 100_000;
    const EVENTS: usize = 512;

    let mut rng = fedel::util::rng::Rng::new(7);
    let finishes: Vec<f64> = (0..SLOTS).map(|_| 1.0 + rng.below(100_000) as f64 * 1e-3).collect();
    // the re-dispatch delay after popping slot s at event step k — pure in
    // (k), so both queue implementations replay the same trace
    let redispatch = |step: usize| 50.0 + (step % 17) as f64;

    let mut linear_trace = 0u64;
    let d_linear = time_median(9, || {
        let mut fin = finishes.clone();
        let mut h = 0u64;
        for step in 0..EVENTS {
            let slot = fin
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1).then(a.0.cmp(&b.0)))
                .map(|(i, _)| i)
                .unwrap();
            h = h.wrapping_mul(31).wrapping_add(slot as u64);
            fin[slot] += redispatch(step);
        }
        linear_trace = std::hint::black_box(h);
    });

    #[derive(PartialEq)]
    struct Ev {
        finish: f64,
        slot: usize,
    }
    impl Eq for Ev {}
    impl PartialOrd for Ev {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for Ev {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            self.finish.total_cmp(&other.finish).then(self.slot.cmp(&other.slot))
        }
    }

    let mut heap_trace = 0u64;
    let d_heap = time_median(9, || {
        let mut q: BinaryHeap<Reverse<Ev>> = finishes
            .iter()
            .enumerate()
            .map(|(slot, &finish)| Reverse(Ev { finish, slot }))
            .collect();
        let mut h = 0u64;
        for step in 0..EVENTS {
            let Reverse(ev) = q.pop().unwrap();
            h = h.wrapping_mul(31).wrapping_add(ev.slot as u64);
            q.push(Reverse(Ev { finish: ev.finish + redispatch(step), slot: ev.slot }));
        }
        heap_trace = std::hint::black_box(h);
    });
    assert_eq!(linear_trace, heap_trace, "heap must pop the same event sequence as the scan");

    let speedup = d_linear.as_secs_f64() / d_heap.as_secs_f64().max(1e-12);
    t.row(vec![
        format!("event queue ({SLOTS} slots x {EVENTS} events), linear scan"),
        format!("{:.2}ms", d_linear.as_secs_f64() * 1e3),
        String::new(),
    ]);
    t.row(vec![
        format!("event queue ({SLOTS} slots x {EVENTS} events), binary heap"),
        format!("{:.2}ms", d_heap.as_secs_f64() * 1e3),
        format!("{speedup:.1}x win"),
    ]);
    println!(
        "event queue [{SLOTS} slots x {EVENTS} events]: linear {:.2}ms, heap {:.2}ms -> {speedup:.1}x",
        d_linear.as_secs_f64() * 1e3,
        d_heap.as_secs_f64() * 1e3,
    );
}

/// The speculative executor's headline number: fedbuff over a skewed
/// (lognormal) 10k-client lazy fleet, serial depth-0 event loop vs
/// speculative dispatch fanned across all cores. Speculation pre-executes
/// predicted future dispatches on the worker pool while the coordinator
/// drains earlier arrivals, so the wall-clock win tracks the hit rate —
/// churn-free, predictions are exact and nearly every commit is a cache
/// hit. Two tripwires: results stay bitwise-identical to the serial
/// reference (speculation is a wall-clock knob, never a semantics knob),
/// and the speedup must not regress below 1.5x on a multi-core host.
fn speculative_async_bench(t: &mut Table) -> anyhow::Result<()> {
    const CLIENTS: usize = 10_000;
    let cfg = |threads: usize, depth: usize| ExperimentCfg {
        model: "mock:8x20000".into(),
        strategy: "fedbuff".into(),
        // heavy-tailed device skew: the exact regime where the serial
        // loop idles waiting on stragglers' arrivals
        fleet: FleetSpec::parse(&format!("lazy{CLIENTS}:lognormal:0:1.0")).unwrap(),
        fleet_sample: 16,
        rounds: 24,
        local_steps: 4,
        lr: 0.1,
        eval_every: 1000, // eval only at the end
        eval_batches: 1,
        slowest_round_secs: 3600.0,
        exec_threads: threads,
        exec_speculate_depth: depth,
        strategy_params: vec![("strategy.fedbuff.buffer_k".to_string(), 2.0)],
        ..Default::default()
    };

    let mut serial_res = None;
    let mut serial = Experiment::build(cfg(1, 0))?;
    let d_serial = time_median(5, || {
        serial_res = Some(std::hint::black_box(serial.run(None).unwrap()));
    });
    let mut spec_res = None;
    let mut spec = Experiment::build(cfg(0, 8))?;
    let d_spec = time_median(5, || {
        spec_res = Some(std::hint::black_box(spec.run(None).unwrap()));
    });

    let (serial_res, spec_res) = (serial_res.unwrap(), spec_res.unwrap());
    assert_eq!(
        serial_res.final_params.len(),
        spec_res.final_params.len(),
        "speculative run changed the model"
    );
    assert!(
        serial_res
            .final_params
            .iter()
            .zip(&spec_res.final_params)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "speculative execution diverged from the serial reference"
    );
    let hits: usize = spec_res.records.iter().map(|r| r.spec_hits).sum();
    let misses: usize = spec_res.records.iter().map(|r| r.spec_misses).sum();
    assert!(hits > 0, "speculation never hit — the bench measured nothing");

    let speedup = d_serial.as_secs_f64() / d_spec.as_secs_f64().max(1e-12);
    t.row(vec![
        format!("speculative async ({CLIENTS}-client skewed fleet), serial depth 0"),
        format!("{:.2}ms", d_serial.as_secs_f64() * 1e3),
        String::new(),
    ]);
    t.row(vec![
        format!("speculative async ({CLIENTS}-client skewed fleet), depth 8 all cores"),
        format!("{:.2}ms", d_spec.as_secs_f64() * 1e3),
        format!("{speedup:.2}x speedup"),
    ]);
    println!(
        "speculative async [{CLIENTS} clients, {hits} hits / {misses} misses]: \
         serial {:.2}ms, speculative {:.2}ms -> {speedup:.2}x",
        d_serial.as_secs_f64() * 1e3,
        d_spec.as_secs_f64() * 1e3,
    );
    if std::thread::available_parallelism().map_or(1, |n| n.get()) >= 2 {
        assert!(
            speedup >= 1.5,
            "speculative dispatch regressed below the 1.5x floor: {speedup:.2}x"
        );
    }
    Ok(())
}

/// Wall-clock of full experiment rounds at exec_threads = 1 vs 0, printed
/// with the parallel speedup.
fn round_throughput(
    t: &mut Table,
    model: &str,
    clients: usize,
    local_steps: usize,
) -> anyhow::Result<()> {
    let cfg = |threads: usize| ExperimentCfg {
        model: model.into(),
        strategy: "fedavg".into(),
        fleet: FleetSpec::Scales(vec![1.0; clients]),
        rounds: 2,
        local_steps,
        lr: 0.1,
        eval_every: 1000, // eval only on the final round
        eval_batches: 1,
        slowest_round_secs: 3600.0,
        exec_threads: threads,
        ..Default::default()
    };
    let mut seq = Experiment::build(cfg(1))?;
    let d_seq = time_median(5, || {
        std::hint::black_box(seq.run(None).unwrap());
    });
    let mut par = Experiment::build(cfg(0))?;
    let d_par = time_median(5, || {
        std::hint::black_box(par.run(None).unwrap());
    });
    let speedup = d_seq.as_secs_f64() / d_par.as_secs_f64().max(1e-12);
    t.row(vec![
        format!("{model} round x{clients} clients, 1 thread"),
        format!("{:.2}ms", d_seq.as_secs_f64() * 1e3),
        String::new(),
    ]);
    t.row(vec![
        format!("{model} round x{clients} clients, all cores"),
        format!("{:.2}ms", d_par.as_secs_f64() * 1e3),
        format!("{speedup:.2}x speedup"),
    ]);
    println!(
        "round throughput [{model}, {clients} clients]: sequential {:.2}ms, parallel {:.2}ms -> {speedup:.2}x",
        d_seq.as_secs_f64() * 1e3,
        d_par.as_secs_f64() * 1e3,
    );
    Ok(())
}

// --- PJRT engine step (needs the `pjrt` feature + artifacts) ------------
#[cfg(feature = "pjrt")]
fn pjrt_benches(t: &mut Table) -> anyhow::Result<()> {
    use fedel::runtime::{Engine, PjrtEngine, TrainSession};
    use std::path::Path;

    let art = Path::new("artifacts/mlp");
    if !art.join("manifest.json").exists() {
        eprintln!("artifacts/mlp missing — skipping PJRT micro-benches (run `make artifacts`)");
        return Ok(());
    }
    let eng = PjrtEngine::open(art)?;
    let man = eng.manifest().clone();
    let params = man.load_init()?;
    let x = vec![0.1f32; man.batch * man.input_shape.iter().product::<usize>()];
    let y = vec![0i32; man.label_len];
    let mask = vec![1.0f32; man.param_count];
    eng.warm(&[man.num_blocks])?;
    let mut sess = eng.session();
    // warm-up execution
    sess.train_step(man.num_blocks, &params, &x, &y, &mask, 0.05)?;
    let d = time_median(21, || {
        let out = sess
            .train_step(man.num_blocks, &params, &x, &y, &mask, 0.05)
            .unwrap();
        std::hint::black_box(out);
    });
    let steps_s = 1.0 / d.as_secs_f64();
    t.row(vec![
        "PJRT train_step (mlp, full exit)".into(),
        format!("{:.2}ms", d.as_secs_f64() * 1e3),
        format!("{steps_s:.0} steps/s"),
    ]);
    let d = time_median(21, || {
        std::hint::black_box(sess.eval_step(&params, &x, &y).unwrap());
    });
    t.row(vec![
        "PJRT eval_step (mlp)".into(),
        format!("{:.2}ms", d.as_secs_f64() * 1e3),
        String::new(),
    ]);
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_benches(_t: &mut Table) -> anyhow::Result<()> {
    eprintln!("pjrt feature disabled — skipping PJRT micro-benches (build with --features pjrt)");
    Ok(())
}
