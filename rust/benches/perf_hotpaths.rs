//! Micro-benchmarks of the L3 hot paths (criterion-style medians; the
//! criterion crate is unavailable offline — see util::prop / report::bench
//! for the in-repo substrates). These feed EXPERIMENTS.md §Perf.

use std::path::Path;

use fedel::elastic::{select, SelectorInput};
use fedel::fl::aggregate::{AggregateRule, MaskedAggregator};
use fedel::manifest::tests_support::chain_manifest;
use fedel::report::bench::{banner, time_median};
use fedel::report::Table;
use fedel::runtime::{Engine, PjrtEngine};
use fedel::timing::{DeviceProfile, TimingCfg, TimingModel};

fn main() -> anyhow::Result<()> {
    banner("perf_hotpaths", "L3 micro-benchmarks (median wall time)");
    let mut t = Table::new("hot paths", &["path", "median", "throughput"]);

    // --- DP selector on a large window ---------------------------------
    let m = chain_manifest(64, 100);
    let tm = TimingModel::profile(&m, &DeviceProfile::orin(), &TimingCfg::default());
    let order: Vec<usize> = (0..64).rev().map(|b| 2 * b).collect();
    let imp: Vec<f64> = (0..64).map(|i| 1.0 + (i % 7) as f64).collect();
    let budget = tm.full_backward_time() * 0.4;
    let d = time_median(21, || {
        let sel = select(&SelectorInput { order: &order, importance: &imp, budget, timing: &tm });
        std::hint::black_box(sel);
    });
    t.row(vec![
        "DP select (64 tensors, 2048 buckets)".into(),
        format!("{:.1}us", d.as_secs_f64() * 1e6),
        String::new(),
    ]);

    // --- masked aggregation over a 100-client x 400k-param fleet --------
    let p = 400_640usize;
    let params = vec![0.5f32; p];
    let mask = vec![1.0f32; p];
    let global = vec![0.0f32; p];
    let d = time_median(9, || {
        let mut agg = MaskedAggregator::new(p, AggregateRule::Masked);
        for _ in 0..20 {
            agg.add(&params, &mask, 1.0, 4, &global);
        }
        std::hint::black_box(agg.finish(&global));
    });
    let gbps = (20.0 * p as f64 * 8.0) / d.as_secs_f64() / 1e9;
    t.row(vec![
        "masked aggregate (20 adds x 400k params)".into(),
        format!("{:.2}ms", d.as_secs_f64() * 1e3),
        format!("{gbps:.1} GB/s"),
    ]);

    // --- mask expansion --------------------------------------------------
    let tensor_mask = vec![1.0f32; m.tensors.len()];
    let d = time_median(21, || {
        std::hint::black_box(m.expand_mask(&tensor_mask));
    });
    t.row(vec![
        format!("expand_mask ({} params)", m.param_count),
        format!("{:.1}us", d.as_secs_f64() * 1e6),
        String::new(),
    ]);

    // --- PJRT engine step (if artifacts exist) --------------------------
    let art = Path::new("artifacts/mlp");
    if art.join("manifest.json").exists() {
        let mut eng = PjrtEngine::open(art)?;
        let man = eng.manifest().clone();
        let params = man.load_init()?;
        let x = vec![0.1f32; man.batch * man.input_shape.iter().product::<usize>()];
        let y = vec![0i32; man.label_len];
        let mask = vec![1.0f32; man.param_count];
        eng.warm(&[man.num_blocks])?;
        // warm-up execution
        eng.train_step(man.num_blocks, &params, &x, &y, &mask, 0.05)?;
        let d = time_median(21, || {
            let out = eng
                .train_step(man.num_blocks, &params, &x, &y, &mask, 0.05)
                .unwrap();
            std::hint::black_box(out);
        });
        let steps_s = 1.0 / d.as_secs_f64();
        t.row(vec![
            "PJRT train_step (mlp, full exit)".into(),
            format!("{:.2}ms", d.as_secs_f64() * 1e3),
            format!("{steps_s:.0} steps/s"),
        ]);
        let d = time_median(21, || {
            std::hint::black_box(eng.eval_step(&params, &x, &y).unwrap());
        });
        t.row(vec![
            "PJRT eval_step (mlp)".into(),
            format!("{:.2}ms", d.as_secs_f64() * 1e3),
            String::new(),
        ]);
    } else {
        eprintln!("artifacts/mlp missing — skipping PJRT micro-benches (run `make artifacts`)");
    }

    t.print();
    Ok(())
}
