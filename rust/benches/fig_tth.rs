//! Figures 12 + 16 — the runtime-threshold ablation: T_th as a fraction of
//! the fastest device's full-model round time. Paper: smaller T_th slows
//! convergence (more window movements for everyone).

use fedel::report::bench::{banner, rounds, Workload};
use fedel::report::Table;
use fedel::sim::experiment::Experiment;

fn main() -> anyhow::Result<()> {
    banner("Figures 12/16", "T_th ablation");
    for w in [Workload::Cifar10Dev, Workload::Speech100Dev] {
        let mut cfg = w.cfg(42);
        cfg.rounds = rounds(12, 100);
        println!("---- {} ----", w.label());
        let mut t = Table::new(
            "convergence vs threshold",
            &["T_th factor", "final_acc", "time_to_90%final (h)", "sim_total_h"],
        );
        for factor in [0.5, 0.75, 1.0, 1.25] {
            let mut cfg_f = cfg.clone();
            cfg_f.t_th_factor = factor;
            let mut exp = Experiment::build(cfg_f)?;
            let res = exp.run(Some("fedel"))?;
            let tta = res
                .time_to_accuracy(0.9 * res.final_acc)
                .unwrap_or(res.sim_total_secs);
            t.row(vec![
                format!("{factor}"),
                format!("{:.3}", res.final_acc),
                format!("{:.1}", tta / 3600.0),
                format!("{:.1}", res.sim_total_secs / 3600.0),
            ]);
        }
        t.print();
    }
    println!("paper shape: smaller T_th -> slower convergence (more sliding-window passes)");
    Ok(())
}
