//! Figure 2 (motivation) — average per-round training time on Xavier vs
//! Orin under FedAvg full-model vs FedAvg+ElasticTrainer, and the
//! accuracy cost of plain ElasticTrainer in FL.

use fedel::report::bench::{banner, rounds, Workload};
use fedel::report::Table;
use fedel::sim::experiment::Experiment;
use fedel::util::stats::mean;

fn main() -> anyhow::Result<()> {
    banner("Figure 2", "FedAvg vs FedAvg+ElasticTrainer: round time + accuracy");
    let mut cfg = Workload::Cifar10Dev.cfg(42);
    cfg.rounds = rounds(20, 120);
    let mut exp = Experiment::build(cfg)?;

    let fedavg = exp.run(Some("fedavg"))?;
    let elastic = exp.run(Some("elastictrainer"))?;

    // Fig 2a: mean per-round client time by device class (clients 0-4 are
    // Xavier, 5-9 Orin in the small10 fleet).
    let by_class = |res: &fedel::fl::server::ExperimentResult, lo: usize, hi: usize| -> f64 {
        let mut times = Vec::new();
        for r in &res.records {
            for &(c, t) in &r.client_secs {
                if (lo..hi).contains(&c) {
                    times.push(t / 60.0);
                }
            }
        }
        mean(&times)
    };
    let mut a = Table::new(
        "Fig 2a: avg round time (min)",
        &["Method", "Xavier", "Orin", "paper:Xavier", "paper:Orin"],
    );
    a.row(vec![
        "FedAvg(full)".into(),
        format!("{:.1}", by_class(&fedavg, 0, 5)),
        format!("{:.1}", by_class(&fedavg, 5, 10)),
        "~72".into(),
        "~36".into(),
    ]);
    a.row(vec![
        "FedAvg+ElasticTrainer".into(),
        format!("{:.1}", by_class(&elastic, 0, 5)),
        format!("{:.1}", by_class(&elastic, 5, 10)),
        "~36".into(),
        "~36".into(),
    ]);
    a.print();

    // Fig 2b: accuracy evolution.
    let mut b = Table::new("Fig 2b: accuracy over time", &["sim_h", "fedavg", "elastic"]);
    let curve_a = fedavg.acc_curve();
    let curve_e = elastic.acc_curve();
    for i in 0..curve_a.len().min(curve_e.len()) {
        b.row(vec![
            format!("{:.1}", curve_a[i].0 / 3600.0),
            format!("{:.3}", curve_a[i].1),
            format!("{:.3}", curve_e[i].1),
        ]);
    }
    b.print();
    println!(
        "shape: elastic equalizes Xavier/Orin round times; final acc {:.3} vs fedavg {:.3} \
         (paper: 40.03% vs 56.13% — elastic loses accuracy)",
        elastic.final_acc, fedavg.final_acc
    );
    Ok(())
}
