//! Figures 11 + 15 — the β ablation: balancing local vs global tensor
//! importance. Paper: β ∈ {0.4, 0.6} beats FedAvg; β ∈ {0, 1} falls below
//! it (fully-global ignores local heterogeneity, fully-local drifts).

use fedel::report::bench::{banner, rounds, Workload};
use fedel::report::Table;
use fedel::sim::experiment::Experiment;

fn main() -> anyhow::Result<()> {
    banner("Figures 11/15", "beta ablation (local vs global importance)");
    for w in [Workload::Cifar10Dev, Workload::TinyIn100Dev] {
        let mut cfg = w.cfg(42);
        cfg.rounds = rounds(12, 100);
        println!("---- {} ----", w.label());
        let mut t = Table::new(
            "time-to-accuracy by beta",
            &["method", "final_acc", "sim_time_h"],
        );
        let mut exp = Experiment::build(cfg.clone())?;
        let fedavg = exp.run(Some("fedavg"))?;
        t.row(vec![
            "fedavg".into(),
            format!("{:.3}", fedavg.final_acc),
            format!("{:.1}", fedavg.sim_total_secs / 3600.0),
        ]);
        for beta in [0.0, 0.2, 0.4, 0.6, 0.8, 1.0] {
            let mut cfg_b = cfg.clone();
            cfg_b
                .strategy_params
                .push(("strategy.fedel.harmonize_weight".to_string(), beta));
            let mut exp_b = Experiment::build(cfg_b)?;
            let res = exp_b.run(Some("fedel"))?;
            t.row(vec![
                format!("fedel beta={beta}"),
                format!("{:.3}", res.final_acc),
                format!("{:.1}", res.sim_total_secs / 3600.0),
            ]);
        }
        t.print();
    }
    println!(
        "paper shape: moderate beta (0.4/0.6) >= fedavg accuracy at a fraction of \
         the time; beta=0 and beta=1 underperform moderate beta"
    );
    Ok(())
}
