//! Figures 4, 10, 14, 18, 19, 20 — tensor-selection traces.
//!
//! Fig 4: one ElasticTrainer-FL round, Xavier vs Orin — slow clients'
//! selections crowd to the back of the DNN.
//! Fig 10/18/19/20: FedEL selections across rounds for one representative
//! device per type — windows slide over the whole model.
//! Fig 14: FedEL vs FedEL-C selection behaviour between windows.
//! Emits CSV series under target/bench_figs/ for plotting.

use std::path::Path;

use fedel::report::bench::{banner, rounds, Workload};
use fedel::sim::experiment::Experiment;
use fedel::util::io::write_csv;

fn selection_rows(
    res: &fedel::fl::server::ExperimentResult,
    client: usize,
) -> Vec<Vec<f64>> {
    res.selections
        .iter()
        .filter(|(_, c, _)| *c == client)
        .flat_map(|(round, _, sel)| {
            sel.iter().map(move |&t| vec![*round as f64, t as f64])
        })
        .collect()
}

fn ascii_trace(res: &fedel::fl::server::ExperimentResult, client: usize, k: usize, nrounds: usize) {
    for round in 0..nrounds {
        let sel: Vec<usize> = res
            .selections
            .iter()
            .filter(|(r, c, _)| *r == round && *c == client)
            .flat_map(|(_, _, s)| s.iter().copied())
            .collect();
        let line: String = (0..k)
            .map(|t| if sel.contains(&t) { '#' } else { '.' })
            .collect();
        println!("  r{round:02} {line}");
    }
}

fn main() -> anyhow::Result<()> {
    banner("Figures 4/10/14/18-20", "tensor-selection traces");
    let out = Path::new("target/bench_figs");

    // ---- Fig 4: ElasticTrainer-FL, one round, Xavier (0) vs Orin (5) ----
    let mut cfg = Workload::Cifar10Dev.cfg(42);
    cfg.rounds = 2;
    cfg.record_selections = true;
    let mut exp = Experiment::build(cfg)?;
    let res = exp.run(Some("elastictrainer"))?;
    let k = exp.ctx.manifest.tensors.len();
    println!("Fig 4 — ElasticTrainer selections (col=tensor, #=selected):");
    println!(" Xavier (slow):");
    ascii_trace(&res, 0, k, 1);
    println!(" Orin (fast):");
    ascii_trace(&res, 5, k, 1);
    write_csv(&out.join("fig4_xavier.csv"), &["round", "tensor"], &selection_rows(&res, 0))?;
    write_csv(&out.join("fig4_orin.csv"), &["round", "tensor"], &selection_rows(&res, 5))?;
    let deepest_block = |client: usize| -> (usize, usize) {
        let blocks: Vec<usize> = res
            .selections
            .iter()
            .filter(|(r, c, _)| *r == 0 && *c == client)
            .flat_map(|(_, _, s)| s.iter().map(|&t| exp.ctx.manifest.tensors[t].block))
            .collect();
        (
            blocks.iter().copied().min().unwrap_or(0),
            blocks.iter().copied().max().unwrap_or(0),
        )
    };
    let (xmin, xmax) = deepest_block(0);
    let (omin, omax) = deepest_block(5);
    println!(
        "shape: Xavier selects blocks {xmin}-{xmax}, Orin {omin}-{omax} \
         (paper Fig 4: slow clients pinned to the back)\n"
    );

    // ---- Fig 10/18/19/20: FedEL selections across rounds per device type ----
    for (fig, w) in [
        ("fig10_tinyin", Workload::TinyIn100Dev),
        ("fig18_cifar", Workload::Cifar10Dev),
        ("fig19_speech", Workload::Speech100Dev),
        ("fig20_reddit", Workload::Reddit100Dev),
    ] {
        let mut cfg = w.cfg(42);
        cfg.rounds = rounds(12, 40);
        cfg.record_selections = true;
        let mut exp = Experiment::build(cfg)?;
        let res = exp.run(Some("fedel"))?;
        let k = exp.ctx.manifest.tensors.len();
        // representative devices: one per distinct scale
        let mut reps: Vec<(String, usize)> = Vec::new();
        for (i, d) in exp.fleet.iter().enumerate() {
            if !reps.iter().any(|(n, _)| n == &d.name) {
                reps.push((d.name.clone(), i));
            }
        }
        println!("{fig} — FedEL selections across rounds ({}):", w.label());
        for (name, client) in &reps {
            println!(" device {name} (client {client}):");
            ascii_trace(&res, *client, k, cfg_rounds_shown());
            write_csv(
                &out.join(format!("{fig}_{name}.csv")),
                &["round", "tensor"],
                &selection_rows(&res, *client),
            )?;
        }
        println!();
    }

    // ---- Fig 14: FedEL vs FedEL-C on a slow client ----
    let mut cfg = Workload::Cifar10Dev.cfg(42);
    cfg.rounds = rounds(10, 24);
    cfg.record_selections = true;
    let mut exp = Experiment::build(cfg)?;
    let k = exp.ctx.manifest.tensors.len();
    let fedel = exp.run(Some("fedel"))?;
    let fedelc = exp.run(Some("fedel-c"))?;
    println!("Fig 14 — FedEL vs FedEL-C selections (Xavier client 0):");
    println!(" FedEL:");
    ascii_trace(&fedel, 0, k, 8);
    println!(" FedEL-C:");
    ascii_trace(&fedelc, 0, k, 8);
    write_csv(&out.join("fig14_fedel.csv"), &["round", "tensor"], &selection_rows(&fedel, 0))?;
    write_csv(&out.join("fig14_fedelc.csv"), &["round", "tensor"], &selection_rows(&fedelc, 0))?;
    println!("CSV series written to target/bench_figs/");
    Ok(())
}

fn cfg_rounds_shown() -> usize {
    if fedel::report::bench::full_scale() {
        24
    } else {
        10
    }
}
