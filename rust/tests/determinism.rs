//! Parallel-vs-sequential determinism: the session-based executor must
//! produce bitwise-identical `ExperimentResult`s at any thread count.
//! This is the design invariant of the Engine/TrainSession split — local
//! training fans out across workers, but sessions are pure functions of
//! their inputs and the server aggregates/observes in plan order.

use fedel::config::{ExperimentCfg, FleetSpec};
use fedel::fl::observer::RoundObserver;
use fedel::fl::server::ClientOutcome;
use fedel::sim::experiment::{run_one, Experiment};
use fedel::strategies::ClientPlan;

fn cfg(strategy: &str, threads: usize) -> ExperimentCfg {
    ExperimentCfg {
        model: "mock:6x50".into(),
        strategy: strategy.into(),
        fleet: FleetSpec::Scales(vec![1.0, 1.5, 2.0, 2.5, 3.0, 4.0, 1.0, 2.0]),
        rounds: 6,
        local_steps: 4,
        lr: 0.3,
        eval_every: 2,
        eval_batches: 2,
        slowest_round_secs: 3600.0,
        exec_threads: threads,
        ..Default::default()
    }
}

fn assert_identical(
    a: &fedel::fl::server::ExperimentResult,
    b: &fedel::fl::server::ExperimentResult,
    label: &str,
) {
    assert_eq!(a.final_params, b.final_params, "{label}: global params diverged");
    assert_eq!(a.final_acc.to_bits(), b.final_acc.to_bits(), "{label}: final_acc");
    assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits(), "{label}: final_loss");
    assert_eq!(
        a.sim_total_secs.to_bits(),
        b.sim_total_secs.to_bits(),
        "{label}: sim_total_secs"
    );
    assert_eq!(a.records.len(), b.records.len(), "{label}: record count");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(
            ra.mean_train_loss.to_bits(),
            rb.mean_train_loss.to_bits(),
            "{label}: round {} loss",
            ra.round
        );
        assert_eq!(ra.sim_time.to_bits(), rb.sim_time.to_bits(), "{label}: round {} clock", ra.round);
        assert_eq!(ra.o1.to_bits(), rb.o1.to_bits(), "{label}: round {} o1", ra.round);
        assert_eq!(
            ra.eval_acc.map(f64::to_bits),
            rb.eval_acc.map(f64::to_bits),
            "{label}: round {} eval",
            ra.round
        );
        assert_eq!(ra.client_secs, rb.client_secs, "{label}: round {} clients", ra.round);
        assert_eq!(ra.dropped, rb.dropped, "{label}: round {} drops", ra.round);
    }
}

#[test]
fn fedel_is_bitwise_identical_across_thread_counts() {
    let seq = run_one(cfg("fedel", 1)).unwrap();
    let four = run_one(cfg("fedel", 4)).unwrap();
    let all_cores = run_one(cfg("fedel", 0)).unwrap();
    assert_identical(&seq, &four, "1 vs 4 threads");
    assert_identical(&seq, &all_cores, "1 thread vs all cores");
}

#[test]
fn every_strategy_is_deterministic_under_parallelism() {
    for name in fedel::strategies::table1_names() {
        let mut c = cfg(name, 1);
        c.rounds = 3;
        let seq = run_one(c).unwrap_or_else(|e| panic!("{name}: {e}"));
        let mut c = cfg(name, 3);
        c.rounds = 3;
        let par = run_one(c).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_identical(&seq, &par, name);
    }
}

/// The async executor's thread-count invariance: the event-driven clock
/// aggregates on the coordinator in event order, and training outcomes
/// are pure, so fedasync/fedbuff results are bitwise-identical at any
/// exec_threads — including the parallel initial fleet-wide fan-out.
#[test]
fn async_strategies_are_bitwise_identical_across_thread_counts() {
    for name in ["fedasync", "fedbuff"] {
        let seq = run_one(cfg(name, 1)).unwrap_or_else(|e| panic!("{name}: {e}"));
        let four = run_one(cfg(name, 4)).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_identical(&seq, &four, name);
        assert_eq!(seq.records.len(), 6, "{name}: one record per aggregation");
        assert!(
            seq.records.iter().all(|r| r.mean_staleness.is_some()),
            "{name}: async records carry staleness stats"
        );
        // the simulated clock is event-driven and monotone (ties are real:
        // same-scale clients dispatched together finish together)
        for w in seq.records.windows(2) {
            assert!(w[1].sim_time >= w[0].sim_time, "{name}: clock must not rewind");
        }
    }
}

/// Availability churn must not disturb the thread-count invariant: every
/// drop decision is a pure hash of (seed, client, iter/time), so the set
/// of discarded uploads — and therefore the aggregation sequence — is
/// identical at any exec_threads.
#[test]
fn churn_runs_are_bitwise_identical_across_thread_counts() {
    for name in ["fedasync", "fedbuff"] {
        let churned = |threads: usize| {
            let mut c = cfg(name, threads);
            c.churn_dropout = 0.5;
            c.churn_period_secs = 4000.0;
            c.churn_avail_frac = 0.75;
            c
        };
        let seq = run_one(churned(1)).unwrap_or_else(|e| panic!("{name}: {e}"));
        let four = run_one(churned(4)).unwrap_or_else(|e| panic!("{name}: {e}"));
        let all_cores = run_one(churned(0)).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_identical(&seq, &four, name);
        assert_identical(&seq, &all_cores, name);
        // dropout 0.5 over dozens of dispatches: churn must actually fire,
        // otherwise this test silently degrades to the churn-free one
        assert!(
            seq.records.iter().any(|r| !r.dropped.is_empty()),
            "{name}: churn never dropped a client"
        );
    }
}

/// Sync-mode churn: dropped clients leave the aggregation but their
/// planned wall time still bounds the round clock — deterministically.
#[test]
fn sync_churn_is_deterministic_and_records_drops() {
    let churned = |threads: usize| {
        let mut c = cfg("fedel", threads);
        c.churn_dropout = 0.4;
        c
    };
    let seq = run_one(churned(1)).unwrap();
    let par = run_one(churned(4)).unwrap();
    assert_identical(&seq, &par, "fedel churn");
    assert!(seq.records.iter().any(|r| !r.dropped.is_empty()), "churn never fired");
    // churn-free baseline diverges: drops change what gets aggregated
    let base = run_one(cfg("fedel", 1)).unwrap();
    assert!(
        base.records.iter().all(|r| r.dropped.is_empty()),
        "baseline must not drop anyone"
    );
    assert_ne!(seq.final_params, base.final_params, "dropout must change the trajectory");
}

/// Speculative dispatch is a pure wall-clock knob: at any depth and any
/// thread count, the aggregation sequence, per-round records (speculation
/// counters aside — they are compared separately below) and final params
/// are bitwise-identical to the depth-0 serial reference. Without churn
/// the lookahead replays the event clock exactly, so every speculation
/// validates as a hit and misses stay zero.
#[test]
fn speculative_execution_is_bitwise_identical_to_serial() {
    for name in ["fedasync", "fedbuff"] {
        let serial = run_one(cfg(name, 1)).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(
            serial.records.iter().all(|r| r.spec_hits == 0 && r.spec_misses == 0),
            "{name}: depth 0 must not count speculations"
        );
        for threads in [1usize, 2, 4] {
            let mut c = cfg(name, threads);
            c.exec_speculate_depth = 4;
            let spec = run_one(c).unwrap_or_else(|e| panic!("{name}@{threads}t: {e}"));
            assert_identical(&serial, &spec, &format!("{name} depth4@{threads}t vs serial"));
            let hits: usize = spec.records.iter().map(|r| r.spec_hits).sum();
            let misses: usize = spec.records.iter().map(|r| r.spec_misses).sum();
            assert!(hits > 0, "{name}@{threads}t: speculation never hit");
            assert_eq!(misses, 0, "{name}@{threads}t: churn-free predictions must be exact");
        }
    }
}

/// The speculation counters themselves are part of the determinism
/// contract: at a fixed depth they are identical per round at any thread
/// count (bindings and validation run on the coordinator in event order;
/// the worker pool is purely an execution backend).
#[test]
fn speculation_counters_are_thread_count_invariant() {
    let spec_cfg = |threads: usize| {
        let mut c = cfg("fedbuff", threads);
        c.exec_speculate_depth = 3;
        c
    };
    let one = run_one(spec_cfg(1)).unwrap();
    let two = run_one(spec_cfg(2)).unwrap();
    let all_cores = run_one(spec_cfg(0)).unwrap();
    assert_identical(&one, &two, "fedbuff depth3 1 vs 2 threads");
    assert_identical(&one, &all_cores, "fedbuff depth3 1 thread vs all cores");
    for other in [&two, &all_cores] {
        for (ra, rb) in one.records.iter().zip(&other.records) {
            assert_eq!(ra.spec_hits, rb.spec_hits, "round {} hits", ra.round);
            assert_eq!(ra.spec_misses, rb.spec_misses, "round {} misses", ra.round);
        }
    }
}

/// Churn dooms are judged at validate time, never at speculate time: a
/// churned speculative run aggregates exactly what the churned serial
/// reference does, and the doom-shifted versions surface as misses that
/// re-execute rather than corrupt.
#[test]
fn churned_speculative_runs_match_serial() {
    for name in ["fedbuff", "fedasync"] {
        let churned = |threads: usize, depth: usize| {
            let mut c = cfg(name, threads);
            c.churn_dropout = 0.5;
            c.churn_period_secs = 4000.0;
            c.churn_avail_frac = 0.75;
            c.exec_speculate_depth = depth;
            c
        };
        let serial = run_one(churned(1, 0)).unwrap_or_else(|e| panic!("{name}: {e}"));
        let spec2 = run_one(churned(2, 4)).unwrap_or_else(|e| panic!("{name}: {e}"));
        let spec4 = run_one(churned(4, 4)).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_identical(&serial, &spec2, &format!("{name} churn depth4@2t vs serial"));
        assert_identical(&serial, &spec4, &format!("{name} churn depth4@4t vs serial"));
        for (ra, rb) in spec2.records.iter().zip(&spec4.records) {
            assert_eq!(ra.spec_hits, rb.spec_hits, "{name}: round {} hits", ra.round);
            assert_eq!(ra.spec_misses, rb.spec_misses, "{name}: round {} misses", ra.round);
        }
        assert!(
            serial.records.iter().any(|r| !r.dropped.is_empty()),
            "{name}: churn never dropped a client"
        );
        let counted: usize = spec2.records.iter().map(|r| r.spec_hits + r.spec_misses).sum();
        assert!(counted > 0, "{name}: speculation never fired under churn");
    }
}

#[test]
fn selection_traces_match_across_thread_counts() {
    let mut a = cfg("fedel", 1);
    a.record_selections = true;
    let mut b = cfg("fedel", 4);
    b.record_selections = true;
    let seq = run_one(a).unwrap();
    let par = run_one(b).unwrap();
    assert!(!seq.selections.is_empty());
    assert_eq!(seq.selections, par.selections);
}

#[test]
fn observers_see_clients_in_plan_order_even_when_parallel() {
    #[derive(Default)]
    struct Order {
        planned: Vec<Vec<usize>>,
        done: Vec<Vec<usize>>,
    }
    impl RoundObserver for Order {
        fn on_round_start(&mut self, _round: usize, plans: &[ClientPlan]) {
            self.planned.push(plans.iter().map(|p| p.client).collect());
            self.done.push(Vec::new());
        }
        fn on_client_done(&mut self, _round: usize, plan: &ClientPlan, out: &ClientOutcome) {
            assert_eq!(plan.client, out.client);
            self.done.last_mut().unwrap().push(plan.client);
        }
    }
    let mut obs = Order::default();
    let mut exp = Experiment::build(cfg("fedel", 0)).unwrap();
    exp.run_observed(None, &mut obs).unwrap();
    assert_eq!(obs.planned.len(), 6);
    assert!(obs.planned.iter().all(|r| !r.is_empty()));
    assert_eq!(obs.planned, obs.done, "per-client callbacks must fire in plan order");
}
