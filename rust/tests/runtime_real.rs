//! Integration tests over the REAL PJRT engine + AOT artifacts. Compiled
//! only with the `pjrt` feature; skipped (pass trivially) when
//! `make artifacts` hasn't run.
#![cfg(feature = "pjrt")]

use std::path::{Path, PathBuf};

use fedel::runtime::{Engine, PjrtEngine, TrainSession};

fn art(model: &str) -> Option<PathBuf> {
    let p = Path::new("artifacts").join(model);
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/{model} missing (run `make artifacts`)");
        None
    }
}

fn batch(m: &fedel::manifest::Manifest, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let mut rng = fedel::util::rng::Rng::new(seed);
    let n: usize = m.batch * m.input_shape.iter().product::<usize>();
    let x: Vec<f32> = match m.task {
        fedel::manifest::Task::Lm => {
            (0..n).map(|_| rng.below(m.num_classes) as f32).collect()
        }
        _ => (0..n).map(|_| rng.normal_f32()).collect(),
    };
    let y: Vec<i32> = (0..m.label_len).map(|_| rng.below(m.num_classes) as i32).collect();
    (x, y)
}

#[test]
fn mlp_train_step_decreases_loss() {
    let Some(dir) = art("mlp") else { return };
    let eng = PjrtEngine::open(&dir).unwrap();
    let m = eng.manifest().clone();
    let mut sess = eng.session();
    let mut p = m.load_init().unwrap();
    let (x, y) = batch(&m, 1);
    let mask = vec![1.0f32; m.param_count];
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..10 {
        let out = sess.train_step(m.num_blocks, &p, &x, &y, &mask, 0.05).unwrap();
        p = out.new_params;
        first.get_or_insert(out.loss);
        last = out.loss;
    }
    assert!(last < first.unwrap(), "{first:?} -> {last}");
}

#[test]
fn mlp_mask_freezes_exactly_the_masked_elements() {
    let Some(dir) = art("mlp") else { return };
    let eng = PjrtEngine::open(&dir).unwrap();
    let m = eng.manifest().clone();
    let mut sess = eng.session();
    let p = m.load_init().unwrap();
    let (x, y) = batch(&m, 2);
    let mut mask = vec![1.0f32; m.param_count];
    // freeze every tensor of block 0
    for t in &m.tensors {
        if t.block == 0 {
            mask[t.offset..t.offset + t.size].fill(0.0);
        }
    }
    let out = sess.train_step(m.num_blocks, &p, &x, &y, &mask, 0.1).unwrap();
    for t in &m.tensors {
        let range = t.offset..t.offset + t.size;
        let moved = range.clone().any(|j| out.new_params[j] != p[j]);
        if t.block == 0 {
            assert!(!moved, "frozen tensor {} moved", t.name);
        }
    }
}

#[test]
fn mlp_exit_semantics_match_manifest() {
    let Some(dir) = art("mlp") else { return };
    let eng = PjrtEngine::open(&dir).unwrap();
    let m = eng.manifest().clone();
    let mut sess = eng.session();
    let p = m.load_init().unwrap();
    let (x, y) = batch(&m, 3);
    let mask = vec![1.0f32; m.param_count];
    let exit = 2;
    let out = sess.train_step(exit, &p, &x, &y, &mask, 0.1).unwrap();
    // sq grads zero for unreached blocks; positive for reached body
    for (i, t) in m.tensors.iter().enumerate() {
        let reached = if t.is_head { t.block == exit - 1 } else { t.block < exit };
        if reached && !t.is_head {
            assert!(out.sq_grads[i] > 0.0, "{} unexpectedly zero", t.name);
        }
        if !reached && !(t.is_head && t.block == exit - 1) {
            assert_eq!(out.sq_grads[i], 0.0, "{} unexpectedly nonzero", t.name);
        }
    }
}

#[test]
fn eval_step_counts_rows() {
    let Some(dir) = art("mlp") else { return };
    let eng = PjrtEngine::open(&dir).unwrap();
    let m = eng.manifest().clone();
    let mut sess = eng.session();
    let p = m.load_init().unwrap();
    let (x, y) = batch(&m, 4);
    let e = sess.eval_step(&p, &x, &y).unwrap();
    assert_eq!(e.rows, m.label_len as f64);
    assert!(e.correct >= 0.0 && e.correct <= e.rows);
    assert!(e.loss_sum > 0.0);
}

#[test]
fn all_models_smoke_one_step() {
    for model in ["mlp", "vgg_cifar", "vgg_tinyin", "resnet_speech", "tinylm_reddit"] {
        let Some(dir) = art(model) else { continue };
        let eng = PjrtEngine::open(&dir).unwrap();
        let m = eng.manifest().clone();
        let mut sess = eng.session();
        let p = m.load_init().unwrap();
        let (x, y) = batch(&m, 5);
        let mask = vec![1.0f32; m.param_count];
        // shallowest and deepest exits
        for exit in [1, m.num_blocks] {
            let out = sess
                .train_step(exit, &p, &x, &y, &mask, 0.02)
                .unwrap_or_else(|e| panic!("{model} exit {exit}: {e}"));
            assert!(out.loss.is_finite(), "{model} exit {exit}");
            assert_eq!(out.new_params.len(), m.param_count);
        }
        let e = sess.eval_step(&p, &x, &y).unwrap();
        assert!(e.loss_sum.is_finite());
    }
}

#[test]
fn init_matches_manifest_sha() {
    for model in ["mlp", "vgg_cifar"] {
        let Some(dir) = art(model) else { continue };
        let m = fedel::manifest::Manifest::load(&dir).unwrap();
        let init = m.load_init().unwrap();
        assert_eq!(init.len(), m.param_count);
        assert!(init.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn lazy_compile_only_touches_used_exits() {
    let Some(dir) = art("mlp") else { return };
    let eng = PjrtEngine::open(&dir).unwrap();
    let m = eng.manifest().clone();
    let mut sess = eng.session();
    let p = m.load_init().unwrap();
    let (x, y) = batch(&m, 6);
    let mask = vec![1.0f32; m.param_count];
    sess.train_step(1, &p, &x, &y, &mask, 0.01).unwrap();
    drop(sess); // sessions merge their exec counts into the engine on drop
    let counts = eng.exec_counts();
    assert_eq!(counts.len(), 1);
    assert_eq!(counts.get(&1), Some(&1));
}

#[test]
fn concurrent_sessions_share_compile_cache() {
    let Some(dir) = art("mlp") else { return };
    let eng = PjrtEngine::open(&dir).unwrap();
    let m = eng.manifest().clone();
    let p = m.load_init().unwrap();
    let (x, y) = batch(&m, 7);
    let mask = vec![1.0f32; m.param_count];
    let compile_before = {
        let mut s = eng.session();
        s.train_step(1, &p, &x, &y, &mask, 0.01).unwrap();
        eng.compile_secs()
    };
    // a second session reuses the cached executable: no new compile time
    let mut s2 = eng.session();
    s2.train_step(1, &p, &x, &y, &mask, 0.01).unwrap();
    drop(s2);
    assert_eq!(eng.compile_secs(), compile_before);
    assert_eq!(eng.exec_counts().get(&1), Some(&2));
}
