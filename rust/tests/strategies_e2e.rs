//! Strategy-level end-to-end behaviour on the mock engine: the paper's
//! qualitative claims as executable checks.

use fedel::config::{ExperimentCfg, FleetSpec};
use fedel::sim::experiment::{run_one, Experiment};

fn cfg(strategy: &str) -> ExperimentCfg {
    ExperimentCfg {
        model: "mock:8x60".into(),
        strategy: strategy.into(),
        fleet: FleetSpec::Scales(vec![1.0, 1.0, 2.0, 2.0, 4.0, 4.0]),
        rounds: 20,
        local_steps: 4,
        lr: 0.4,
        eval_every: 4,
        eval_batches: 2,
        slowest_round_secs: 3600.0,
        ..Default::default()
    }
}

/// Round time of the method relative to FedAvg's.
fn relative_round_time(name: &str) -> f64 {
    let avg = run_one(cfg("fedavg")).unwrap();
    let m = run_one(cfg(name)).unwrap();
    m.records[2].round_secs / avg.records[2].round_secs
}

#[test]
fn partial_methods_shrink_rounds() {
    for name in ["elastictrainer", "heterofl", "depthfl", "timelyfl", "fedel"] {
        let r = relative_round_time(name);
        assert!(r < 0.75, "{name} relative round time {r}");
    }
}

#[test]
fn pyramidfl_does_not_shrink_rounds_much() {
    // the paper's observation: client selection alone barely reduces
    // wall-clock because a selected straggler still costs full time
    let r = relative_round_time("pyramidfl");
    assert!(r > 0.5, "pyramidfl shrank rounds too much: {r}");
}

#[test]
fn fedel_eval_accuracy_not_worse_than_elastic() {
    // Limitation #1/#2 fix: with the mock quadratic objective, FedEL's
    // sliding window trains shallow tensors the plain ElasticTrainer
    // starves, so its pseudo-accuracy (distance to target over ALL
    // coordinates) should be at least as good.
    let elastic = run_one(cfg("elastictrainer")).unwrap();
    let fedel = run_one(cfg("fedel")).unwrap();
    assert!(
        fedel.final_acc >= elastic.final_acc * 0.98,
        "fedel {} vs elastic {}",
        fedel.final_acc,
        elastic.final_acc
    );
}

#[test]
fn depthfl_assigns_stable_depths() {
    let mut exp = Experiment::build(cfg("depthfl")).unwrap();
    let res = exp.run(None).unwrap();
    // all rounds have the same per-round structure (fixed sub-models)
    let t0 = res.records[0].round_secs;
    for r in &res.records {
        assert!((r.round_secs - t0).abs() < 1e-6);
    }
}

#[test]
fn fedel_round_times_hover_near_t_th() {
    let mut exp = Experiment::build(cfg("fedel")).unwrap();
    let res = exp.run(None).unwrap();
    let t_th = exp.ctx.t_th;
    let mean_round = fedel::util::stats::mean(
        &res.records.iter().map(|r| r.round_secs - 30.0).collect::<Vec<_>>(),
    );
    // Appendix B.3: FedEL deviates from T_th by 3-19%
    assert!(
        mean_round < t_th * 1.6 && mean_round > t_th * 0.3,
        "mean round {mean_round} vs T_th {t_th}"
    );
}

#[test]
fn prox_variants_stay_closer_to_global() {
    // FedProx's proximal term should reduce client drift: final model of
    // fedprox+fedel stays closer to its starting point per round than
    // plain fedel under identical seeds (weak proxy: both converge).
    let plain = run_one(cfg("fedel")).unwrap();
    let prox = run_one(cfg("fedprox+fedel")).unwrap();
    assert!(prox.final_acc.is_finite() && plain.final_acc.is_finite());
    assert!(prox.final_acc > 0.0);
}

#[test]
fn fednova_fedel_converges() {
    let res = run_one(cfg("fednova+fedel")).unwrap();
    let curve = res.acc_curve();
    assert!(res.final_acc >= curve[0].1, "{curve:?}");
}

#[test]
fn coverage_grows_over_rounds_for_fedel() {
    // union of trained tensors grows as windows slide
    let mut c = cfg("fedel");
    c.record_selections = true;
    let res = run_one(c).unwrap();
    // restrict to a straggler (client 4, scale 4.0): its per-round window
    // is a strict subset, so the union must keep growing as windows slide
    let union_at = |upto: usize| -> usize {
        let mut seen = std::collections::BTreeSet::new();
        for (r, c, sel) in &res.selections {
            if *r <= upto && *c == 4 {
                seen.extend(sel.iter().copied());
            }
        }
        seen.len()
    };
    assert!(union_at(19) > union_at(0), "{} vs {}", union_at(19), union_at(0));
}

#[test]
fn fedbuff_staleness_exp_zero_is_neutral_and_nonzero_is_not() {
    // The registry tunable `strategy.fedbuff.staleness_exp` decays each
    // buffered delta by 1/(1+s)^exp inside the flush average. exp=0 must
    // be bitwise-identical to the plain data-size weighting (the guard
    // skips the powf entirely), while a real exponent must change the
    // aggregate on a heterogeneous fleet where staleness varies.
    let base = run_one(cfg("fedbuff")).unwrap();
    let mut zero = cfg("fedbuff");
    zero.strategy_params = vec![("strategy.fedbuff.staleness_exp".into(), 0.0)];
    let zero = run_one(zero).unwrap();
    assert_eq!(base.final_params, zero.final_params, "exp=0 must be bitwise-neutral");
    let mut decayed = cfg("fedbuff");
    decayed.strategy_params = vec![("strategy.fedbuff.staleness_exp".into(), 2.0)];
    let decayed = run_one(decayed).unwrap();
    assert_ne!(base.final_params, decayed.final_params, "exp=2 must change the flush average");
}

#[test]
fn heterofl_coverage_is_fractional() {
    let mut c = cfg("heterofl");
    c.record_selections = true;
    let mut exp = Experiment::build(c).unwrap();
    let res = exp.run(None).unwrap();
    // slow clients train a strict subset of elements -> mean_coverage < 1
    assert!(res.records[0].mean_coverage < 1.0);
    assert!(res.records[0].mean_coverage > 0.0);
}
