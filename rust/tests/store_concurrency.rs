//! Concurrent store access: many writers against ONE store. Run-id
//! allocation is lockfile-guarded and *reserving* (`fresh_run_id` creates
//! the run directory while holding the lock), so threads — and, by the
//! same mechanism, whole processes — can never both observe an id free
//! and clobber each other's `runs/<id>/`.

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::time::Duration;

use fedel::config::{ExperimentCfg, FleetSpec};
use fedel::sim::experiment::Experiment;
use fedel::store::checkpoint::CheckpointObserver;
use fedel::store::schema::RunStatus;
use fedel::store::RunStore;

fn scratch(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("fedel-concurrency-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn concurrent_allocators_never_collide_on_run_ids() {
    let dir = scratch("alloc");
    let store = RunStore::open(&dir).unwrap();
    const THREADS: usize = 8;
    const PER_THREAD: usize = 6;
    // Every thread fights for the same strategy+seed id namespace — the
    // exact two-writers-see-the-same-free-suffix race this store had.
    let ids: Vec<String> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                s.spawn(|| {
                    (0..PER_THREAD)
                        .map(|_| store.fresh_run_id("fedel", 42).unwrap())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let unique: BTreeSet<&String> = ids.iter().collect();
    assert_eq!(
        unique.len(),
        THREADS * PER_THREAD,
        "run ids collided under contention: {ids:?}"
    );
    for id in &ids {
        assert!(dir.join("runs").join(id).is_dir(), "{id} was not reserved on disk");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_checkpointed_runs_share_one_store() {
    let dir = scratch("runs");
    let store = RunStore::open(&dir).unwrap();
    const WRITERS: usize = 4;
    std::thread::scope(|s| {
        for _ in 0..WRITERS {
            let store = &store;
            s.spawn(move || {
                // Identical configs on purpose: same id namespace, and
                // identical parameter blobs exercising concurrent
                // `put_blob` of the same content.
                let cfg = ExperimentCfg {
                    model: "mock:4x20".into(),
                    strategy: "fedavg".into(),
                    fleet: FleetSpec::Scales(vec![1.0, 2.0]),
                    rounds: 4,
                    local_steps: 2,
                    lr: 0.3,
                    eval_every: 2,
                    eval_batches: 1,
                    slowest_round_secs: 3600.0,
                    exec_threads: 1,
                    ..Default::default()
                };
                let mut exp = Experiment::build(cfg).unwrap();
                let mut ckpt =
                    CheckpointObserver::create(store, &exp.cfg, "fedavg", 2).unwrap();
                exp.run_from(None, &mut ckpt, None).unwrap();
                assert!(ckpt.take_error().is_none(), "checkpointing failed under contention");
            });
        }
    });

    // every writer's run landed, every manifest parses, no id collided
    let runs = store.list().unwrap();
    assert_eq!(runs.len(), WRITERS, "a concurrent writer clobbered another's run");
    let unique: BTreeSet<&str> = runs.iter().map(|m| m.id.as_str()).collect();
    assert_eq!(unique.len(), WRITERS);
    for m in &runs {
        assert_eq!(m.status, RunStatus::Complete, "{}", m.id);
        assert_eq!(m.records.len(), 4, "{}", m.id);
        store.latest_params(&m.id).expect("stored params must verify");
    }

    // Identical runs dedup to two blobs: the round-2 checkpoint (now
    // superseded by the round-4 one in every manifest — an orphan) and
    // the round-4/final params (live). gc must sweep exactly the orphan.
    let gc = store.gc_blobs(Duration::ZERO, false).unwrap();
    assert_eq!((gc.live, gc.swept), (1, 1), "{gc:?}");
    for m in &store.list().unwrap() {
        store.latest_params(&m.id).expect("live params must survive gc");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
