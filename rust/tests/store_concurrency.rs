//! Concurrent store access: many writers against ONE store. Run-id
//! allocation is lockfile-guarded and *reserving* (`fresh_run_id` creates
//! the run directory while holding the lock), so threads — and, by the
//! same mechanism, whole processes — can never both observe an id free
//! and clobber each other's `runs/<id>/`.

use std::collections::BTreeSet;
use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use fedel::config::{ExperimentCfg, FleetSpec};
use fedel::sim::experiment::Experiment;
use fedel::store::backend::remote::default_cache_dir;
use fedel::store::backend::serve::StoreServer;
use fedel::store::checkpoint::CheckpointObserver;
use fedel::store::schema::{CampaignManifest, CellState, RunStatus, CAMPAIGN_SCHEMA_VERSION};
use fedel::store::RunStore;
use fedel::util::json::Json;
use fedel::util::unix_now;

fn scratch(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("fedel-concurrency-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn concurrent_allocators_never_collide_on_run_ids() {
    let dir = scratch("alloc");
    let store = RunStore::open(&dir).unwrap();
    const THREADS: usize = 8;
    const PER_THREAD: usize = 6;
    // Every thread fights for the same strategy+seed id namespace — the
    // exact two-writers-see-the-same-free-suffix race this store had.
    let ids: Vec<String> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                s.spawn(|| {
                    (0..PER_THREAD)
                        .map(|_| store.fresh_run_id("fedel", 42).unwrap())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let unique: BTreeSet<&String> = ids.iter().collect();
    assert_eq!(
        unique.len(),
        THREADS * PER_THREAD,
        "run ids collided under contention: {ids:?}"
    );
    for id in &ids {
        assert!(dir.join("runs").join(id).is_dir(), "{id} was not reserved on disk");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_checkpointed_runs_share_one_store() {
    let dir = scratch("runs");
    let store = RunStore::open(&dir).unwrap();
    const WRITERS: usize = 4;
    std::thread::scope(|s| {
        for _ in 0..WRITERS {
            let store = &store;
            s.spawn(move || {
                // Identical configs on purpose: same id namespace, and
                // identical parameter blobs exercising concurrent
                // `put_blob` of the same content.
                let cfg = ExperimentCfg {
                    model: "mock:4x20".into(),
                    strategy: "fedavg".into(),
                    fleet: FleetSpec::Scales(vec![1.0, 2.0]),
                    rounds: 4,
                    local_steps: 2,
                    lr: 0.3,
                    eval_every: 2,
                    eval_batches: 1,
                    slowest_round_secs: 3600.0,
                    exec_threads: 1,
                    ..Default::default()
                };
                let mut exp = Experiment::build(cfg).unwrap();
                let mut ckpt =
                    CheckpointObserver::create(store, &exp.cfg, "fedavg", 2).unwrap();
                exp.run_from(None, &mut ckpt, None).unwrap();
                assert!(ckpt.take_error().is_none(), "checkpointing failed under contention");
            });
        }
    });

    // every writer's run landed, every manifest parses, no id collided
    let runs = store.list().unwrap();
    assert_eq!(runs.len(), WRITERS, "a concurrent writer clobbered another's run");
    let unique: BTreeSet<&str> = runs.iter().map(|m| m.id.as_str()).collect();
    assert_eq!(unique.len(), WRITERS);
    for m in &runs {
        assert_eq!(m.status, RunStatus::Complete, "{}", m.id);
        assert_eq!(m.records.len(), 4, "{}", m.id);
        store.latest_params(&m.id).expect("stored params must verify");
    }

    // Identical runs dedup to two blobs: the round-2 checkpoint (now
    // superseded by the round-4 one in every manifest — an orphan) and
    // the round-4/final params (live). gc must sweep exactly the orphan.
    let gc = store.gc_blobs(Duration::ZERO, false).unwrap();
    assert_eq!((gc.live, gc.swept), (1, 1), "{gc:?}");
    for m in &store.list().unwrap() {
        store.latest_params(&m.id).expect("live params must survive gc");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Remote backend: the same contention drills through one `runs serve`
// instance, plus wire-fault injection (corruption, dropped connections)
// through a byte-level proxy.

/// Id allocation and campaign cell claims race safely when every writer is
/// a *remote* client of one served store: allocation runs on the serving
/// host under its lock, and cell claims go through the conditional-PUT CAS.
#[test]
fn remote_store_races_resolve_like_local_ones() {
    let dir = scratch("remote-race");
    let server = StoreServer::start(&dir, "127.0.0.1:0", 4).unwrap();
    let store = RunStore::open(format!("http://{}", server.addr())).unwrap();

    const THREADS: usize = 4;
    const PER_THREAD: usize = 4;
    let ids: Vec<String> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                s.spawn(|| {
                    (0..PER_THREAD)
                        .map(|_| store.fresh_run_id("fedel", 42).unwrap())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
    });
    let unique: BTreeSet<&String> = ids.iter().collect();
    assert_eq!(unique.len(), THREADS * PER_THREAD, "remote run ids collided: {ids:?}");
    for id in &ids {
        assert!(dir.join("runs").join(id).is_dir(), "{id} was not reserved on the serving host");
    }

    // Cell claims: first writer wins, every racer agrees on the winner,
    // and the stored assignment is one of the proposed run ids.
    let now = unix_now();
    store
        .save_campaign(&CampaignManifest {
            schema_version: CAMPAIGN_SCHEMA_VERSION,
            name: "race".into(),
            created_unix: now,
            updated_unix: now,
            spec: Json::obj(vec![]),
            cells: vec![CellState::unassigned("base".into())],
        })
        .unwrap();
    let winners: Vec<String> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|i| {
                let store = &store;
                s.spawn(move || {
                    store
                        .claim_campaign_cell("race", "base", None, &format!("contender-{i}"))
                        .unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let agreed: BTreeSet<&String> = winners.iter().collect();
    assert_eq!(agreed.len(), 1, "racers disagree on the claim winner: {winners:?}");
    assert!(winners[0].starts_with("contender-"), "{winners:?}");
    let stored = store.load_campaign("race").unwrap();
    assert_eq!(stored.cells[0].run_id.as_deref(), Some(winners[0].as_str()));

    // Worker leases on the claimed cell CAS the same way: one racer
    // acquires, everyone else sees exactly who holds it and how stale
    // the heartbeat is — and a non-holder's release is a no-op.
    let outcomes: Vec<fedel::store::LeaseOutcome> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|i| {
                let store = &store;
                s.spawn(move || {
                    store
                        .lease_campaign_cell("race", "base", &format!("worker-{i}"), 3600)
                        .unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let acquired: Vec<&fedel::store::LeaseOutcome> = outcomes
        .iter()
        .filter(|o| matches!(o, fedel::store::LeaseOutcome::Acquired { .. }))
        .collect();
    assert_eq!(acquired.len(), 1, "exactly one lease racer may win: {outcomes:?}");
    let holder = store.load_campaign("race").unwrap().cells[0].worker.clone().unwrap();
    for o in &outcomes {
        if let fedel::store::LeaseOutcome::Held { worker, age_secs } = o {
            assert_eq!(worker, &holder, "losers must see the real holder");
            assert!(*age_secs < 3600, "a just-taken lease cannot be stale");
        }
    }
    store.release_campaign_lease("race", "base", "nobody").unwrap();
    assert_eq!(
        store.load_campaign("race").unwrap().cells[0].worker.as_deref(),
        Some(holder.as_str()),
        "a non-holder's release must not drop the lease"
    );
    store.release_campaign_lease("race", "base", &holder).unwrap();
    assert!(store.load_campaign("race").unwrap().cells[0].worker.is_none());

    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// One raw HTTP exchange against a served store (the server closes after
/// each response, so a fresh connection per request is the protocol).
fn upload_request(
    addr: SocketAddr,
    method: &str,
    target: &str,
    headers: &[(String, String)],
    body: &[u8],
) -> fedel::store::backend::http::Response {
    use fedel::store::backend::http::{read_response, write_request};
    let mut s = TcpStream::connect(addr).unwrap();
    write_request(&mut s, method, target, "test", headers, body).unwrap();
    read_response(&mut std::io::BufReader::new(s), false).unwrap()
}

/// Upload-session GC: sessions abandoned before commit are swept when a
/// new upload opens and their age exceeds the server's upload max-age —
/// while sessions inside the age window keep accepting chunks.
#[test]
fn abandoned_upload_sessions_are_garbage_collected() {
    let hdr = |k: &str, v: &str| vec![(k.to_string(), v.to_string())];
    let dir = scratch("upload-gc");

    // Zero max-age: every pre-existing session counts as abandoned the
    // moment another upload opens.
    let server =
        StoreServer::start_with_upload_gc(&dir, "127.0.0.1:0", 2, Duration::ZERO).unwrap();
    let addr = server.addr();
    let open_a = upload_request(addr, "POST", "/v2/runs/blobs/uploads/", &[], b"");
    assert_eq!(open_a.status, 202);
    let loc_a = open_a.header("Location").unwrap().to_string();
    let patch =
        upload_request(addr, "PATCH", &loc_a, &hdr("Content-Range", "0-3"), b"abcd");
    assert_eq!(patch.status, 202);

    // opening B sweeps the (instantly stale) half-done A...
    let open_b = upload_request(addr, "POST", "/v2/runs/blobs/uploads/", &[], b"");
    assert_eq!(open_b.status, 202);
    let loc_b = open_b.header("Location").unwrap().to_string();
    let gone =
        upload_request(addr, "PATCH", &loc_a, &hdr("Content-Range", "0-3"), b"abcd");
    assert_eq!(gone.status, 404, "swept session must be gone");

    // ...while B, created after the sweep, still commits into a blob
    let payload = b"precious upload";
    let range = format!("0-{}", payload.len() - 1);
    let patch_b =
        upload_request(addr, "PATCH", &loc_b, &hdr("Content-Range", &range), payload);
    assert_eq!(patch_b.status, 202);
    let digest = format!("sha256:{}", fedel::util::sha256::hex(payload));
    let put =
        upload_request(addr, "PUT", &format!("{loc_b}?digest={digest}"), &[], b"");
    assert_eq!(put.status, 201, "commit after the sweep must publish");
    let blob = fedel::store::schema::BlobRef {
        digest,
        size: payload.len() as u64,
        media_type: "application/octet-stream".into(),
    };
    assert_eq!(RunStore::open(&dir).unwrap().get_blob(&blob).unwrap(), payload);
    server.shutdown();

    // A generous max-age spares in-flight sessions: A survives B's open
    // and keeps appending from its recorded offset.
    let server =
        StoreServer::start_with_upload_gc(&dir, "127.0.0.1:0", 2, Duration::from_secs(3600))
            .unwrap();
    let addr = server.addr();
    let open_a = upload_request(addr, "POST", "/v2/runs/blobs/uploads/", &[], b"");
    assert_eq!(open_a.status, 202);
    let loc_a = open_a.header("Location").unwrap().to_string();
    let patch =
        upload_request(addr, "PATCH", &loc_a, &hdr("Content-Range", "0-3"), b"abcd");
    assert_eq!(patch.status, 202);
    let open_b = upload_request(addr, "POST", "/v2/runs/blobs/uploads/", &[], b"");
    assert_eq!(open_b.status, 202);
    let still =
        upload_request(addr, "PATCH", &loc_a, &hdr("Content-Range", "4-7"), b"efgh");
    assert_eq!(still.status, 202, "a session inside the age window must survive sweeps");
    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// A byte-level TCP proxy in front of a store server with two fault
/// injectors: `corrupt` flips the last byte of every server response
/// (which lands in a blob GET's body), and `arm_drop` kills one
/// connection after a cumulative client->server byte count — mid-upload.
struct FaultProxy {
    addr: SocketAddr,
    corrupt: Arc<AtomicBool>,
    drop_limit: Arc<AtomicUsize>,
    drop_seen: Arc<AtomicUsize>,
}

impl FaultProxy {
    fn start(upstream: SocketAddr) -> FaultProxy {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let corrupt = Arc::new(AtomicBool::new(false));
        let drop_limit = Arc::new(AtomicUsize::new(0));
        let drop_seen = Arc::new(AtomicUsize::new(0));
        {
            let corrupt = Arc::clone(&corrupt);
            let drop_limit = Arc::clone(&drop_limit);
            let drop_seen = Arc::clone(&drop_seen);
            std::thread::spawn(move || {
                for client in listener.incoming() {
                    let Ok(client) = client else { return };
                    let corrupt = Arc::clone(&corrupt);
                    let drop_limit = Arc::clone(&drop_limit);
                    let drop_seen = Arc::clone(&drop_seen);
                    std::thread::spawn(move || {
                        forward(client, upstream, corrupt, drop_limit, drop_seen)
                    });
                }
            });
        }
        FaultProxy { addr, corrupt, drop_limit, drop_seen }
    }

    /// One-shot: kill the connection that crosses `bytes` of cumulative
    /// client->server traffic from now on. Disarms itself after firing.
    fn arm_drop(&self, bytes: usize) {
        self.drop_seen.store(0, Ordering::SeqCst);
        self.drop_limit.store(bytes, Ordering::SeqCst);
    }

    fn drop_fired(&self) -> bool {
        self.drop_limit.load(Ordering::SeqCst) == 0
    }
}

fn forward(
    client: TcpStream,
    upstream: SocketAddr,
    corrupt: Arc<AtomicBool>,
    drop_limit: Arc<AtomicUsize>,
    drop_seen: Arc<AtomicUsize>,
) {
    let Ok(server) = TcpStream::connect(upstream) else { return };
    let (Ok(mut c_read), Ok(mut s_write)) = (client.try_clone(), server.try_clone()) else {
        return;
    };
    let client_kill = client.try_clone().ok();
    // client -> server: count bytes and, when an armed drop limit is
    // crossed, tear down both sides of the connection mid-request.
    let c2s = std::thread::spawn(move || {
        let mut buf = [0u8; 16 * 1024];
        loop {
            let n = match c_read.read(&mut buf) {
                Ok(0) | Err(_) => break,
                Ok(n) => n,
            };
            let limit = drop_limit.load(Ordering::SeqCst);
            if limit != 0 && drop_seen.fetch_add(n, Ordering::SeqCst) + n >= limit {
                drop_limit.store(0, Ordering::SeqCst); // one-shot
                let _ = s_write.shutdown(Shutdown::Both);
                if let Some(c) = &client_kill {
                    let _ = c.shutdown(Shutdown::Both);
                }
                return;
            }
            if s_write.write_all(&buf[..n]).is_err() {
                break;
            }
        }
        let _ = s_write.shutdown(Shutdown::Write);
    });
    // server -> client: the store server closes after one response, so
    // buffering to EOF frames it exactly. Corruption flips the LAST byte
    // of the response — the tail of the body — leaving the status line,
    // headers and Content-Length intact so only digest checks can object.
    let mut s_read = server;
    let mut resp = Vec::new();
    if s_read.read_to_end(&mut resp).is_ok() && !resp.is_empty() {
        if corrupt.load(Ordering::SeqCst) {
            *resp.last_mut().unwrap() ^= 0xff;
        }
        let mut c_write = client;
        let _ = c_write.write_all(&resp);
        let _ = c_write.shutdown(Shutdown::Write);
    }
    let _ = c2s.join();
}

/// Wire faults stay contained: a corrupted pull is rejected by digest
/// verification and never enters the local blob cache, and a connection
/// dropped mid-upload is healed by the resumable upload protocol.
#[test]
fn wire_faults_are_contained() {
    let dir = scratch("remote-faults");
    let server = StoreServer::start(&dir, "127.0.0.1:0", 2).unwrap();
    let proxy = FaultProxy::start(server.addr());
    let local = RunStore::open(&dir).unwrap();
    let remote = RunStore::open(format!("http://{}", proxy.addr)).unwrap();

    // -- corruption drill -------------------------------------------------
    // Unique content per process so a previous run's cache entry can't
    // satisfy the pull before the corrupted wire bytes are even seen.
    let params: Vec<f32> =
        (0..2000).map(|i| (i as f32) * 0.5 + std::process::id() as f32).collect();
    let blob = local.put_params(&params).unwrap();
    let hex = blob.digest.strip_prefix("sha256:").unwrap();
    let cached = default_cache_dir().join(hex);
    let _ = std::fs::remove_file(&cached);

    proxy.corrupt.store(true, Ordering::SeqCst);
    let err = remote.get_params(&blob).expect_err("corrupted pull must be rejected");
    let msg = format!("{err:#}");
    assert!(msg.contains("digest"), "unexpected error for corrupted pull: {msg}");
    assert!(!cached.exists(), "corrupted bytes must never enter the blob cache");

    proxy.corrupt.store(false, Ordering::SeqCst);
    let pulled = remote.get_params(&blob).unwrap();
    assert_eq!(pulled, params, "clean retry must round-trip exactly");
    assert!(cached.exists(), "verified bytes should be cached for reuse");

    // -- dropped-connection drill -----------------------------------------
    // 200k f32 = 800 KB = four 256 KiB upload chunks. Arm the one-shot
    // drop at 300 KB of cumulative client->server traffic: the first
    // PATCH (~262 KB) survives, the second dies mid-body, and the client
    // must recover by querying the session offset and resuming.
    let big: Vec<f32> = (0..200_000).map(|i| ((i % 9973) as f32) * 0.125 - 3.0).collect();
    proxy.arm_drop(300_000);
    let big_ref = remote.put_params(&big).unwrap();
    assert!(proxy.drop_fired(), "the drop never triggered — upload was not exercised");
    assert_eq!(local.get_params(&big_ref).unwrap(), big, "resumed upload must be byte-exact");

    server.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
