//! Integration tests over the mock engine: full FL experiments exercising
//! every module boundary (config -> fleet -> timing -> strategy -> server
//! -> aggregation -> metrics) without PJRT or artifacts.

use fedel::config::{ExperimentCfg, FleetSpec};
use fedel::metrics::energy::energy_report;
use fedel::metrics::memory::memory_bytes;
use fedel::report::{table1_rows, Table1Row};
use fedel::sim::experiment::{run_one, Experiment};
use fedel::strategies::{table1_names, Strategy};

fn mock_cfg(strategy: &str, rounds: usize) -> ExperimentCfg {
    ExperimentCfg {
        model: "mock:8x60".into(),
        strategy: strategy.into(),
        fleet: FleetSpec::Scales(vec![1.0, 1.0, 2.0, 2.0, 4.0]),
        rounds,
        local_steps: 4,
        lr: 0.3,
        eval_every: 3,
        eval_batches: 2,
        slowest_round_secs: 3600.0,
        ..Default::default()
    }
}

#[test]
fn all_strategies_complete_and_report() {
    for name in table1_names() {
        let res = run_one(mock_cfg(name, 6)).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(res.records.len(), 6, "{name}");
        assert!(res.sim_total_secs > 0.0, "{name}");
        assert!(res.final_acc.is_finite(), "{name}");
        for r in &res.records {
            assert!(r.participants > 0, "{name} round {} empty", r.round);
            assert!(r.round_secs > 0.0);
            assert!(r.mean_coverage >= 0.0 && r.mean_coverage <= 1.0);
        }
    }
}

#[test]
fn async_strategies_complete_with_staleness_and_pace() {
    for name in ["fedasync", "fedbuff"] {
        let res = run_one(mock_cfg(name, 6)).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(res.strategy, name);
        assert_eq!(res.records.len(), 6, "{name}: one record per aggregation");
        assert!(res.final_acc.is_finite(), "{name}");
        let mut last = 0.0;
        for r in &res.records {
            // monotone, not strictly increasing: same-scale clients
            // dispatched together arrive together
            assert!(r.sim_time >= last, "{name}: event clock must not rewind");
            assert!((r.sim_time - last - r.round_secs).abs() < 1e-6, "{name}");
            last = r.sim_time;
            assert!(r.mean_staleness.is_some(), "{name}");
            assert!(r.max_staleness.unwrap() >= r.mean_staleness.unwrap(), "{name}");
            match name {
                "fedasync" => assert_eq!(r.participants, 1, "{name}: per-arrival"),
                _ => assert_eq!(r.participants, 4, "{name}: default buffer_k"),
            }
        }
        // fast devices lap slow ones: with scales {1,1,2,2,4}, the early
        // aggregations are dominated by the two fast clients
        let early: Vec<usize> = res.records[0].client_secs.iter().map(|&(c, _)| c).collect();
        assert!(
            early.iter().all(|&c| c < 4),
            "{name}: the 4x straggler cannot win the first arrivals ({early:?})"
        );
    }
}

#[test]
fn bandwidth_comm_model_charges_payloads_and_partial_training_banks_savings() {
    // With comm free, round time is pure compute; with a bandwidth model
    // it grows by the slowest client's transfer time — and fedavg (full
    // uploads) pays strictly more than fedel (masked uploads).
    let overhead = |strategy: &str| {
        let mut free = mock_cfg(strategy, 2);
        free.comm_secs = 0.0;
        // T_th below even the fastest device's full round, so every fedel
        // client partial-trains: all masked uploads are strict subsets and
        // the round's comm overhead is strictly below the full-payload one
        // no matter which client binds the round. (fedavg ignores T_th.)
        free.t_th_factor = 0.5;
        // Link speeds chosen so transfer times (sub-second) stay far below
        // the straggler's compute margin over the runner-up (tens of
        // seconds): the slowest client binds the round in both runs, and
        // the overhead is exactly that client's transfer time.
        let mut priced = free.clone();
        priced.comm_up_mbps = 0.05;
        priced.comm_down_mbps = 0.2;
        priced.comm_latency_secs = 0.05;
        let t_free = run_one(free).unwrap().records[0].round_secs;
        let t_priced = run_one(priced).unwrap().records[0].round_secs;
        assert!(t_priced > t_free, "{strategy}: transfers must cost time");
        t_priced - t_free
    };
    let fedavg = overhead("fedavg");
    let fedel = overhead("fedel");
    assert!(
        fedel < fedavg,
        "masked uploads must be cheaper: fedel +{fedel}s vs fedavg +{fedavg}s"
    );
}

#[test]
fn sim_clock_is_monotone_and_cumulative() {
    let res = run_one(mock_cfg("fedel", 10)).unwrap();
    let mut last = 0.0;
    for r in &res.records {
        assert!(r.sim_time > last);
        assert!((r.sim_time - last - r.round_secs).abs() < 1e-6);
        last = r.sim_time;
    }
}

#[test]
fn fedavg_round_time_is_slowest_client_time() {
    let res = run_one(mock_cfg("fedavg", 3)).unwrap();
    for r in &res.records {
        let max_client = r
            .client_secs
            .iter()
            .map(|&(_, t)| t)
            .fold(0.0f64, f64::max);
        assert!((r.round_secs - 30.0 - max_client).abs() < 1e-6);
    }
}

#[test]
fn fedel_beats_fedavg_wallclock_on_heterogeneous_fleet() {
    let avg = run_one(mock_cfg("fedavg", 8)).unwrap();
    let fedel = run_one(mock_cfg("fedel", 8)).unwrap();
    assert!(
        fedel.sim_total_secs < 0.6 * avg.sim_total_secs,
        "fedel {} vs fedavg {}",
        fedel.sim_total_secs,
        avg.sim_total_secs
    );
}

#[test]
fn timelyfl_rounds_cost_exactly_the_deadline() {
    let mut exp = Experiment::build(mock_cfg("timelyfl", 4)).unwrap();
    let res = exp.run(None).unwrap();
    for r in &res.records {
        assert!((r.round_secs - 30.0 - exp.ctx.t_th).abs() < 1e-6);
    }
}

#[test]
fn pyramidfl_subsamples_clients() {
    let res = run_one(mock_cfg("pyramidfl", 6)).unwrap();
    assert!(res.records.iter().all(|r| r.participants < 5));
}

#[test]
fn o1_bias_zero_for_fedavg_positive_for_partial_methods() {
    let avg = run_one(mock_cfg("fedavg", 4)).unwrap();
    for r in &avg.records {
        assert!(r.o1.abs() < 1e-9, "fedavg round {} o1 {}", r.round, r.o1);
    }
    let fedel = run_one(mock_cfg("fedel", 6)).unwrap();
    assert!(fedel.mean_o1() > 0.0);
}

#[test]
fn rollback_o1_is_spikier_than_norollback() {
    // Table 4's robust signature: rollback keeps revisiting layers, so its
    // per-round O1 fluctuates (paper std 8.62) while no-rollback pins all
    // windows and stabilizes (paper std 2.62). The MEAN comparison is
    // fleet/workload-dependent and is reported (not asserted) by
    // benches/table4.rs on the paper's actual workload.
    let roll = run_one(mock_cfg("fedel", 24)).unwrap();
    let noroll = run_one(mock_cfg("fedel-norollback", 24)).unwrap();
    assert!(
        roll.std_o1() > noroll.std_o1(),
        "rollback std {} vs norollback std {}",
        roll.std_o1(),
        noroll.std_o1()
    );
    assert!(roll.mean_o1().is_finite() && noroll.mean_o1() > 0.0);
}

#[test]
fn record_selections_produces_traces() {
    let mut cfg = mock_cfg("fedel", 4);
    cfg.record_selections = true;
    let res = run_one(cfg).unwrap();
    assert!(!res.selections.is_empty());
    for (round, client, sel) in &res.selections {
        assert!(*round < 4);
        assert!(*client < 5);
        assert!(!sel.is_empty());
    }
}

#[test]
fn experiments_are_deterministic_per_seed() {
    let a = run_one(mock_cfg("fedel", 5)).unwrap();
    let b = run_one(mock_cfg("fedel", 5)).unwrap();
    assert_eq!(a.final_acc, b.final_acc);
    assert_eq!(a.sim_total_secs, b.sim_total_secs);
    let mut cfg = mock_cfg("fedel", 5);
    cfg.seed = 43;
    let c = run_one(cfg).unwrap();
    assert_ne!(a.final_acc, c.final_acc);
}

#[test]
fn table1_rows_assemble_from_results() {
    let avg = run_one(mock_cfg("fedavg", 6)).unwrap();
    let fedel = run_one(mock_cfg("fedel", 6)).unwrap();
    let rows: Vec<Table1Row> = table1_rows(&[avg, fedel], 0.9, false);
    assert_eq!(rows.len(), 2);
    assert!(rows[0].speedup_vs_fedavg.is_none());
    assert!(rows[1].speedup_vs_fedavg.unwrap() > 1.0);
}

#[test]
fn memory_model_orders_strategies_sensibly() {
    let mut exp = Experiment::build(mock_cfg("fedel", 2)).unwrap();
    let m = exp.ctx.manifest.clone();
    let global = vec![0.0f32; m.param_count];
    let k = m.tensors.len();
    // FedAvg full footprint vs FedEL's windowed footprint on the slowest client
    let full = memory_bytes(&m, m.num_blocks, &vec![1.0; k]);
    let mut fedel = fedel::strategies::by_name("fedel", &exp.ctx, 0.6, 1).unwrap();
    let plans = fedel.plan_round(0, &exp.ctx, &global);
    let straggler = plans.iter().find(|p| p.client == 4).unwrap();
    let win = memory_bytes(&m, straggler.exit, &straggler.mask.tensor_coverage());
    assert!(win.total() < full.total());
    let _ = exp.run(None).unwrap();
}

#[test]
fn energy_report_tracks_active_time_differences() {
    let mut exp = Experiment::build(mock_cfg("fedavg", 4)).unwrap();
    let avg = exp.run(Some("fedavg")).unwrap();
    let fedel = exp.run(Some("fedel")).unwrap();
    let e_avg = energy_report(&avg, &exp.fleet).unwrap();
    let e_fedel = energy_report(&fedel, &exp.fleet).unwrap();
    assert!(
        e_fedel.total_kj < e_avg.total_kj,
        "fedel {} kJ vs fedavg {} kJ",
        e_fedel.total_kj,
        e_avg.total_kj
    );
}

#[test]
fn beta_extremes_run_without_error() {
    for beta in [0.0, 1.0] {
        let mut cfg = mock_cfg("fedel", 4);
        cfg.strategy_params
            .push(("strategy.fedel.harmonize_weight".to_string(), beta));
        let res = run_one(cfg).unwrap();
        assert!(res.final_acc.is_finite());
    }
}

#[test]
fn single_client_fleet_works() {
    let mut cfg = mock_cfg("fedel", 4);
    cfg.fleet = FleetSpec::Scales(vec![1.0]);
    let res = run_one(cfg).unwrap();
    assert_eq!(res.records[0].participants, 1);
}

#[test]
fn extreme_straggler_fleet_works() {
    let mut cfg = mock_cfg("fedel", 5);
    cfg.fleet = FleetSpec::Scales(vec![1.0, 20.0]);
    let res = run_one(cfg).unwrap();
    assert!(res.final_acc.is_finite());
}
