//! Campaign crash safety: a grid killed mid-flight — at the campaign
//! level (workers stop claiming cells) and at the cell level
//! (`halt_after` kills rounds between checkpoints) — resumes to
//! completion with previously-finished cells skipped, and every cell's
//! stored records and parameters bitwise-identical to an uninterrupted
//! campaign's. Extends `tests/resume.rs`' invariant from one run to whole
//! grids, across the generic `--sweep` axes (including strategy-declared
//! tunables) and across the v1 -> v2 campaign-manifest migration.

use std::path::PathBuf;

use fedel::config::ExperimentCfg;
use fedel::report::Target;
use fedel::sim::campaign::{
    grouped_report, report, run_campaign, CampaignCfg, CampaignCell, CellRun,
};
use fedel::store::schema::{CampaignManifest, CellState, RunManifest, RunStatus};
use fedel::store::RunStore;
use fedel::util::json::Json;

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fedel-campaign-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// 2 strategies x 2 seeds on the mock engine, one worker so the
/// campaign-level kill lands on a deterministic cell boundary.
fn grid(name: &str) -> CampaignCfg {
    let base = ExperimentCfg {
        model: "mock:6x50".into(),
        fleet: fedel::config::FleetSpec::Scales(vec![1.0, 2.0, 4.0]),
        rounds: 6,
        local_steps: 2,
        lr: 0.3,
        eval_every: 2,
        eval_batches: 2,
        slowest_round_secs: 3600.0,
        exec_threads: 1,
        ..Default::default()
    };
    let mut cfg = CampaignCfg::new(name, base);
    cfg.axis("strategy=fedavg,fedel").unwrap();
    cfg.axis("seed=1,2").unwrap();
    cfg.checkpoint_every = 2;
    cfg.workers = 1;
    cfg
}

/// The stored run behind each cell label, via the campaign manifest.
fn cell_runs(store: &RunStore, name: &str) -> Vec<(String, RunManifest)> {
    let m = store.load_campaign(name).unwrap();
    m.cells
        .iter()
        .map(|c| {
            let id = c.run_id.as_ref().unwrap_or_else(|| panic!("cell {} unassigned", c.label));
            (c.label.clone(), store.load_manifest(id).unwrap())
        })
        .collect()
}

fn assert_stores_identical(a: &RunStore, b: &RunStore, name: &str) {
    let runs_a = cell_runs(a, name);
    let runs_b = cell_runs(b, name);
    assert_eq!(runs_a.len(), runs_b.len());
    for ((label_a, ma), (label_b, mb)) in runs_a.iter().zip(&runs_b) {
        assert_eq!(label_a, label_b);
        assert_eq!(ma.status, RunStatus::Complete, "{label_a}");
        assert_eq!(mb.status, RunStatus::Complete, "{label_a}");
        assert_eq!(ma.records.len(), mb.records.len(), "{label_a}: record count");
        for (ra, rb) in ma.records.iter().zip(&mb.records) {
            assert_eq!(ra.round, rb.round, "{label_a}");
            assert_eq!(
                ra.sim_time.to_bits(),
                rb.sim_time.to_bits(),
                "{label_a}: round {} clock",
                ra.round
            );
            assert_eq!(
                ra.mean_train_loss.to_bits(),
                rb.mean_train_loss.to_bits(),
                "{label_a}: round {} loss",
                ra.round
            );
            assert_eq!(
                ra.eval_acc.map(f64::to_bits),
                rb.eval_acc.map(f64::to_bits),
                "{label_a}: round {} eval",
                ra.round
            );
            assert_eq!(ra.dropped, rb.dropped, "{label_a}: round {} drops", ra.round);
        }
        let fa = ma.final_state.as_ref().unwrap();
        let fb = mb.final_state.as_ref().unwrap();
        assert_eq!(fa.final_acc.to_bits(), fb.final_acc.to_bits(), "{label_a}");
        assert_eq!(
            a.get_params(&fa.params).unwrap(),
            b.get_params(&fb.params).unwrap(),
            "{label_a}: final params diverged"
        );
    }
}

#[test]
fn campaign_runs_grid_reports_and_is_idempotent() {
    let dir = scratch("idempotent");
    let store = RunStore::open(&dir).unwrap();
    let cfg = grid("sweep");

    let outcome = run_campaign(&store, &cfg).unwrap();
    assert!(outcome.complete(), "{outcome:?}");
    assert!(outcome.cells.iter().all(|c| c.status == CellRun::Completed));
    assert_eq!(outcome.cells.len(), 4);

    // every cell's run is stored and complete, under its overlay label
    for (label, m) in cell_runs(&store, "sweep") {
        assert_eq!(m.status, RunStatus::Complete, "{label}");
        assert_eq!(m.records.len(), 6, "{label}");
        assert!(label.starts_with("strategy="), "{label}");
    }

    // the whole-grid report defaults its baseline to the fedavg cell
    let man = store.load_campaign("sweep").unwrap();
    let rep = report(&store, &man, Target::Default, None).unwrap();
    assert_eq!(rep.rows.len(), 4);
    assert_eq!(rep.baseline, man.cells[0].run_id.clone().unwrap());
    // an explicit strategy baseline resolves too
    let rep = report(&store, &man, Target::Default, Some("fedel")).unwrap();
    assert!(rep.baseline.starts_with("fedel"));

    // Table-3 shape: collapse the seed axis into mean ± std per strategy
    let agg = grouped_report(&store, &man, "seed", Target::Default, None).unwrap();
    assert_eq!(agg.over, "seed");
    assert_eq!(agg.baseline.as_deref(), Some("fedavg"));
    assert_eq!(agg.rows.len(), 2, "{agg:?}");
    assert_eq!(agg.rows[0].label, "strategy=fedavg");
    assert_eq!(agg.rows[1].label, "strategy=fedel");
    for row in &agg.rows {
        assert_eq!(row.cells, 2, "{row:?}");
        assert_eq!(row.final_acc.unwrap().n, 2, "{row:?}");
    }
    // collapsing a non-axis errors loudly
    assert!(grouped_report(&store, &man, "data.alpha", Target::Default, None).is_err());

    // running the finished campaign again touches nothing
    let again = run_campaign(&store, &cfg).unwrap();
    assert!(again.complete());
    assert!(again.cells.iter().all(|c| c.status == CellRun::Skipped), "{again:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The acceptance drill: kill the campaign after two cells, then kill the
/// remaining cells mid-round via `halt_after`, then resume everything —
/// completed cells skipped, killed cells continued from their
/// checkpoints, results bitwise-identical to a never-interrupted campaign.
#[test]
fn killed_campaign_resumes_skipping_completed_cells_bitwise_identically() {
    let reference_dir = scratch("reference");
    let reference = RunStore::open(&reference_dir).unwrap();
    let uninterrupted = run_campaign(&reference, &grid("sweep")).unwrap();
    assert!(uninterrupted.complete());

    let dir = scratch("killed");
    let store = RunStore::open(&dir).unwrap();

    // phase 1: the campaign process dies after two cells finished
    let mut phase1 = grid("sweep");
    phase1.halt_after_cells = Some(2);
    let out = run_campaign(&store, &phase1).unwrap();
    assert!(out.halted);
    // (skipped, completed, failed, pending, pruned)
    assert_eq!(out.counts(), (0, 2, 0, 2, 0), "{out:?}");

    // phase 2: the remaining cells get killed *inside* a round span —
    // after round 3, between the round-2 and round-4 checkpoints
    let mut phase2 = grid("sweep");
    phase2.halt_after = Some(3);
    let out = run_campaign(&store, &phase2).unwrap();
    assert!(!out.complete());
    assert_eq!(out.counts(), (2, 0, 2, 0, 0), "{out:?}");
    for c in out.failures() {
        match &c.status {
            CellRun::Failed(msg) => assert!(msg.contains("halted"), "{msg}"),
            other => panic!("{other:?}"),
        }
    }
    // what the kill left on disk: checkpoints at round 2, 2 records
    let man = store.load_campaign("sweep").unwrap();
    for cell in &man.cells[2..] {
        let run = store.load_manifest(cell.run_id.as_ref().unwrap()).unwrap();
        assert_eq!(run.status, RunStatus::Running, "{}", cell.label);
        assert_eq!(run.checkpoint.as_ref().unwrap().completed, 2, "{}", cell.label);
        assert_eq!(run.records.len(), 2, "{}", cell.label);
    }

    // phase 3: plain resume — completed cells skipped, killed cells
    // continued from their checkpoints to completion
    let out = run_campaign(&store, &grid("sweep")).unwrap();
    assert!(out.complete(), "{out:?}");
    assert_eq!(out.counts(), (2, 2, 0, 0, 0), "{out:?}");

    assert_stores_identical(&reference, &store, "sweep");
    let _ = std::fs::remove_dir_all(&reference_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn same_name_different_grid_is_rejected() {
    let dir = scratch("mismatch");
    let store = RunStore::open(&dir).unwrap();
    let mut small = grid("sweep");
    small.halt_after_cells = Some(1);
    run_campaign(&store, &small).unwrap();

    let mut other = grid("sweep");
    other.axes[1] = fedel::config::params::SweepAxis::parse(
        fedel::config::params::ParamSpace::shared(),
        "seed=7,8",
    )
    .unwrap();
    let err = run_campaign(&store, &other).unwrap_err();
    assert!(err.to_string().contains("different grid"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The tentpole acceptance drill: a campaign sweeping a strategy-declared
/// tunable (`strategy.fedel.harmonize_weight`) and a data parameter
/// (`data.alpha`) alongside strategy and seed axes — entirely through
/// registered keys — runs, kill-resumes bitwise-identically, and the
/// grouped report collapses the seed axis into mean ± std per cell with
/// per-seed-matched speedups vs the fedavg baseline.
#[test]
fn swept_strategy_and_data_params_kill_resume_and_aggregate() {
    fn sweep_grid(name: &str) -> CampaignCfg {
        let base = ExperimentCfg {
            model: "mock:4x20".into(),
            fleet: fedel::config::FleetSpec::Scales(vec![1.0, 3.0]),
            rounds: 4,
            local_steps: 2,
            lr: 0.3,
            eval_every: 2,
            eval_batches: 2,
            slowest_round_secs: 3600.0,
            exec_threads: 1,
            ..Default::default()
        };
        let mut cfg = CampaignCfg::new(name, base);
        cfg.axis("strategy=fedavg,fedel").unwrap();
        cfg.axis("seed=1,2").unwrap();
        cfg.axis("data.alpha=0.1,0.5").unwrap();
        cfg.axis("strategy.fedel.harmonize_weight=0.2,0.8").unwrap();
        cfg.checkpoint_every = 2;
        cfg.workers = 2;
        cfg
    }

    let reference_dir = scratch("sweep-ref");
    let reference = RunStore::open(&reference_dir).unwrap();
    assert!(run_campaign(&reference, &sweep_grid("table3")).unwrap().complete());

    // the swept values actually land in the stored per-cell configs
    for (label, m) in cell_runs(&reference, "table3") {
        let alpha: f64 = if label.contains("data.alpha=0.1") { 0.1 } else { 0.5 };
        assert_eq!(m.config.alpha, alpha, "{label}");
        let hw = if label.contains("harmonize_weight=0.2") { 0.2 } else { 0.8 };
        assert_eq!(
            m.config.strategy_params,
            vec![("strategy.fedel.harmonize_weight".to_string(), hw)],
            "{label}"
        );
    }
    // the harmonize_weight axis changes fedel's results (the knob reaches
    // the policy, not just the manifest)
    let runs = cell_runs(&reference, "table3");
    let fedel_02 = runs
        .iter()
        .find(|(l, _)| l.contains("strategy=fedel") && l.contains("seed=1")
            && l.contains("alpha=0.1") && l.contains("=0.2"))
        .unwrap();
    let fedel_08 = runs
        .iter()
        .find(|(l, _)| l.contains("strategy=fedel") && l.contains("seed=1")
            && l.contains("alpha=0.1") && l.contains("=0.8"))
        .unwrap();
    // Any divergent signal proves the knob reached the selector: round
    // losses, eval curve, or the final global model.
    let differs = fedel_02
        .1
        .records
        .iter()
        .zip(&fedel_08.1.records)
        .any(|(a, b)| {
            a.mean_train_loss.to_bits() != b.mean_train_loss.to_bits()
                || a.eval_acc.map(f64::to_bits) != b.eval_acc.map(f64::to_bits)
        })
        || reference
            .get_params(&fedel_02.1.final_state.as_ref().unwrap().params)
            .unwrap()
            != reference
                .get_params(&fedel_08.1.final_state.as_ref().unwrap().params)
                .unwrap();
    assert!(differs, "harmonize_weight sweep did not reach the policy");

    // kill mid-round, resume, compare bitwise
    let dir = scratch("sweep-killed");
    let store = RunStore::open(&dir).unwrap();
    let mut killed = sweep_grid("table3");
    killed.halt_after = Some(3);
    let out = run_campaign(&store, &killed).unwrap();
    assert!(!out.complete());
    let out = run_campaign(&store, &sweep_grid("table3")).unwrap();
    assert!(out.complete(), "{out:?}");
    assert_stores_identical(&reference, &store, "table3");

    // Table-3 aggregation: 16 cells collapse over seed into 8 groups of 2
    let man = reference.load_campaign("table3").unwrap();
    let agg = grouped_report(&reference, &man, "seed", Target::Default, None).unwrap();
    assert_eq!(agg.rows.len(), 8, "{agg:?}");
    assert_eq!(agg.baseline.as_deref(), Some("fedavg"));
    for row in &agg.rows {
        assert_eq!(row.cells, 2, "{row:?}");
        let acc = row.final_acc.expect("every cell stores a final accuracy");
        assert_eq!(acc.n, 2);
        assert!(acc.std >= 0.0);
        let tta = row.time_to_target.expect("default target is reachable");
        assert_eq!(tta.n, 2, "{row:?}");
        let speedup = row.speedup_vs_baseline.expect("fedavg baseline is on the grid");
        assert_eq!(speedup.n, 2, "{row:?}");
        if row.label.starts_with("strategy=fedavg") {
            assert!((speedup.mean - 1.0).abs() < 1e-9, "baseline speedup is 1.0: {row:?}");
            assert!(speedup.std.abs() < 1e-9, "{row:?}");
        }
    }
    // JSON form carries the aggregates
    let j = Json::parse(&agg.to_json().to_string_pretty()).unwrap();
    assert_eq!(j.s("aggregated_over").unwrap(), "seed");
    assert_eq!(j.arr("groups").unwrap().len(), 8);

    let _ = std::fs::remove_dir_all(&reference_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Async acceptance drill: a campaign sweeping the synchronous baselines
/// against an asynchronous one (`fedbuff`) under a non-degenerate
/// communication model (`comm.up_mbps` / `comm.down_mbps` via the `--set`
/// layer) completes, kill-resumes bitwise-identically (the async cell
/// included — its in-flight clocks and staleness buffer ride the
/// checkpoint), and the whole-grid report times every cell — async ones
/// included — to the matched accuracy target.
#[test]
fn async_cells_sweep_with_comm_model_and_kill_resume() {
    fn async_grid(name: &str) -> CampaignCfg {
        let base = ExperimentCfg {
            model: "mock:4x20".into(),
            fleet: fedel::config::FleetSpec::Scales(vec![1.0, 2.0, 3.0]),
            rounds: 6,
            local_steps: 2,
            lr: 0.3,
            eval_every: 2,
            eval_batches: 2,
            slowest_round_secs: 3600.0,
            exec_threads: 1,
            ..Default::default()
        };
        let mut cfg = CampaignCfg::new(name, base);
        cfg.axis("strategy=fedavg,fedel,fedbuff").unwrap();
        cfg.set = fedel::config::params::SpecOverlay::parse(
            fedel::config::params::ParamSpace::shared(),
            &["comm.up_mbps=10", "comm.down_mbps=50", "comm.latency_secs=0.1",
              "strategy.fedbuff.buffer_k=2"],
        )
        .unwrap();
        cfg.checkpoint_every = 2;
        cfg.workers = 1;
        cfg
    }

    let reference_dir = scratch("async-ref");
    let reference = RunStore::open(&reference_dir).unwrap();
    assert!(run_campaign(&reference, &async_grid("async")).unwrap().complete());

    // the comm model landed in every stored cell config, and the async
    // cell recorded staleness
    for (label, m) in cell_runs(&reference, "async") {
        assert_eq!(m.config.comm_up_mbps, 10.0, "{label}");
        assert_eq!(m.config.comm_down_mbps, 50.0, "{label}");
        assert_eq!(m.records.len(), 6, "{label}");
        if label.contains("fedbuff") {
            assert!(
                m.records.iter().all(|r| r.mean_staleness.is_some()),
                "{label}: async rounds must carry staleness"
            );
            assert!(
                m.records.iter().all(|r| r.participants == 2),
                "{label}: buffer_k=2 flushes in pairs"
            );
        } else {
            assert!(m.records.iter().all(|r| r.mean_staleness.is_none()), "{label}");
        }
    }

    // whole-grid report: every cell (async included) gets a
    // time-to-accuracy at the matched default target
    let man = reference.load_campaign("async").unwrap();
    let rep = report(&reference, &man, Target::Default, None).unwrap();
    assert_eq!(rep.rows.len(), 3);
    for row in &rep.rows {
        assert!(
            row.time_to_target.is_some(),
            "{}: no time-to-accuracy in the async-cell report",
            row.strategy
        );
    }

    // kill mid-flight (aggregation 3, between the 2- and 4-checkpoints),
    // resume, demand bitwise identity — async cell included
    let dir = scratch("async-killed");
    let store = RunStore::open(&dir).unwrap();
    let mut killed = async_grid("async");
    killed.halt_after = Some(3);
    let out = run_campaign(&store, &killed).unwrap();
    assert!(!out.complete());
    let out = run_campaign(&store, &async_grid("async")).unwrap();
    assert!(out.complete(), "{out:?}");
    assert_stores_identical(&reference, &store, "async");

    let _ = std::fs::remove_dir_all(&reference_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The fleet-churn acceptance drill: availability churn as a sweepable
/// scenario axis. `fleet.churn.dropout=0;0.1;0.3` (the semicolon
/// separator `--sweep` accepts for any axis) expands into cells whose
/// stored configs carry the churn key, whose records log the dropped
/// clients, and which kill-resume bitwise-identically; `campaign report
/// --over seed` then collapses the seed axis per (strategy, dropout)
/// group.
#[test]
fn churn_dropout_sweep_runs_kill_resumes_and_groups_over_seed() {
    fn churn_grid(name: &str) -> CampaignCfg {
        let base = ExperimentCfg {
            model: "mock:4x20".into(),
            fleet: fedel::config::FleetSpec::Scales(vec![1.0, 2.0, 3.0]),
            rounds: 4,
            local_steps: 2,
            lr: 0.3,
            eval_every: 2,
            eval_batches: 2,
            slowest_round_secs: 3600.0,
            exec_threads: 1,
            ..Default::default()
        };
        let mut cfg = CampaignCfg::new(name, base);
        cfg.axis("strategy=fedavg,fedbuff").unwrap();
        cfg.axis("seed=1,2").unwrap();
        cfg.axis("fleet.churn.dropout=0;0.1;0.3").unwrap();
        cfg.checkpoint_every = 2;
        cfg.workers = 1;
        cfg
    }

    let reference_dir = scratch("churn-ref");
    let reference = RunStore::open(&reference_dir).unwrap();
    let out = run_campaign(&reference, &churn_grid("churn")).unwrap();
    assert!(out.complete(), "{out:?}");
    assert_eq!(out.cells.len(), 12, "2 strategies x 2 seeds x 3 dropouts");

    // the swept dropout lands in every stored cell config, and churn
    // fires exactly where it should: never at dropout=0, visibly at 0.3
    let runs = cell_runs(&reference, "churn");
    let mut heavy_dropped = 0usize;
    for (label, m) in &runs {
        let dropout = if label.contains("dropout=0.3") {
            0.3
        } else if label.contains("dropout=0.1") {
            0.1
        } else {
            0.0
        };
        assert_eq!(m.config.churn_dropout, dropout, "{label}");
        assert_eq!(m.records.len(), 4, "{label}");
        if dropout == 0.0 {
            assert!(
                m.records.iter().all(|r| r.dropped.is_empty()),
                "{label}: churn-free cell recorded drops"
            );
        } else if dropout == 0.3 {
            heavy_dropped += m.records.iter().filter(|r| !r.dropped.is_empty()).count();
        }
    }
    assert!(heavy_dropped > 0, "dropout=0.3 never dropped a client in any cell");

    // kill every cell mid-round, resume, demand bitwise identity — churn
    // decisions are pure (seed, client, time) hashes, so the drop
    // sequence survives the process boundary
    let dir = scratch("churn-killed");
    let store = RunStore::open(&dir).unwrap();
    let mut killed = churn_grid("churn");
    killed.halt_after = Some(3);
    let out = run_campaign(&store, &killed).unwrap();
    assert!(!out.complete());
    let out = run_campaign(&store, &churn_grid("churn")).unwrap();
    assert!(out.complete(), "{out:?}");
    assert_stores_identical(&reference, &store, "churn");

    // `campaign report --over seed`: 12 cells collapse into 6
    // (strategy, dropout) groups of 2 seeds each
    let man = reference.load_campaign("churn").unwrap();
    let agg = grouped_report(&reference, &man, "seed", Target::Default, None).unwrap();
    assert_eq!(agg.over, "seed");
    assert_eq!(agg.rows.len(), 6, "{agg:?}");
    assert_eq!(agg.baseline.as_deref(), Some("fedavg"));
    for row in &agg.rows {
        assert_eq!(row.cells, 2, "{row:?}");
        assert!(row.label.contains("fleet.churn.dropout="), "{row:?}");
        assert_eq!(row.final_acc.unwrap().n, 2, "{row:?}");
    }

    let _ = std::fs::remove_dir_all(&reference_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The distributed-store acceptance drill: the same grid driven through
/// `--store http://...` against a `runs serve` instance, killed mid-cell
/// and resumed over HTTP, must leave run manifests and final parameters
/// bitwise-identical to a local-directory campaign's — the store backend
/// is invisible to results.
#[test]
fn remote_store_campaign_kill_resume_matches_local_bitwise() {
    use fedel::store::backend::serve::StoreServer;

    let reference_dir = scratch("http-ref");
    let reference = RunStore::open(&reference_dir).unwrap();
    assert!(run_campaign(&reference, &grid("sweep")).unwrap().complete());

    let dir = scratch("http-served");
    let server = StoreServer::start(&dir, "127.0.0.1:0", 4).unwrap();
    let store = RunStore::open(format!("http://{}", server.addr())).unwrap();
    assert_eq!(store.location(), format!("http://{}", server.addr()));

    // kill every cell mid-round (after round 3, between the round-2 and
    // round-4 checkpoints), then resume — all over HTTP
    let mut killed = grid("sweep");
    killed.halt_after = Some(3);
    let out = run_campaign(&store, &killed).unwrap();
    assert!(!out.complete());
    let out = run_campaign(&store, &grid("sweep")).unwrap();
    assert!(out.complete(), "{out:?}");

    // results identical through the remote read path...
    assert_stores_identical(&reference, &store, "sweep");
    // ...and the stored run manifests are byte-identical modulo wall-clock
    // timestamps: same ids, records, checkpoints (content-addressed blob
    // digests included), and final state.
    let runs_a = cell_runs(&reference, "sweep");
    let runs_b = cell_runs(&store, "sweep");
    let norm = |m: &RunManifest| {
        let mut m = m.clone();
        m.created_unix = 0;
        m.updated_unix = 0;
        m.to_json().to_string_pretty()
    };
    for ((label, ma), (_, mb)) in runs_a.iter().zip(&runs_b) {
        assert_eq!(norm(ma), norm(mb), "{label}: manifest bytes diverged");
    }

    server.shutdown();
    let _ = std::fs::remove_dir_all(&reference_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Campaigns persisted by the PR-3-era schema (v1: four fixed axes,
/// `fedavg-s1-fsmall10-t1` labels) migrate in place on the next run and
/// resume bitwise-identically: spec converts to axes form, labels are
/// rewritten, run assignments survive.
#[test]
fn v1_campaign_manifest_migrates_and_resumes_bitwise_identically() {
    // The four-axis grid exactly as a v1 campaign would have expanded it.
    fn v1_equivalent_spec(cfg: &CampaignCfg) -> Json {
        Json::obj(vec![
            ("base", cfg.base.to_json()),
            ("strategies", Json::from_strs(&["fedavg", "fedel"])),
            (
                "seeds",
                Json::Arr(vec![Json::Str("1".into()), Json::Str("2".into())]),
            ),
            ("fleets", Json::from_strs(&["1,2,4"])),
            ("t_th_factors", Json::from_f64s(&[1.0])),
            ("checkpoint_every", Json::Num(cfg.checkpoint_every as f64)),
        ])
    }

    // Grid matching tests::grid() but with the fleet + T_th axes the v1
    // schema always carried (singletons, same resolved configs).
    fn four_axis_grid(name: &str) -> CampaignCfg {
        let mut cfg = grid(name);
        cfg.base.fleet = fedel::config::FleetSpec::Scales(vec![1.0, 2.0, 4.0]);
        cfg.axis("fleet=1,2,4").unwrap();
        cfg.axis("time.t_th_factor=1").unwrap();
        cfg
    }

    let reference_dir = scratch("migrate-ref");
    let reference = RunStore::open(&reference_dir).unwrap();
    assert!(run_campaign(&reference, &four_axis_grid("legacy")).unwrap().complete());

    // phase 1: half-run the campaign, kill mid-round
    let dir = scratch("migrate");
    let store = RunStore::open(&dir).unwrap();
    let mut phase1 = four_axis_grid("legacy");
    phase1.halt_after = Some(3);
    let out = run_campaign(&store, &phase1).unwrap();
    assert!(!out.complete());

    // phase 2: rewrite the manifest exactly as the v1 schema stored it —
    // v1 spec shape, v1-style labels, run assignments kept
    let m2 = store.load_campaign("legacy").unwrap();
    let cfg = four_axis_grid("legacy");
    let v1_labels: Vec<String> = ["fedavg-s1", "fedavg-s2", "fedel-s1", "fedel-s2"]
        .iter()
        .map(|p| format!("{p}-f1,2,4-t1"))
        .collect();
    let downgraded = CampaignManifest {
        schema_version: 1,
        name: m2.name.clone(),
        created_unix: m2.created_unix,
        updated_unix: m2.updated_unix,
        spec: v1_equivalent_spec(&cfg),
        cells: m2
            .cells
            .iter()
            .zip(&v1_labels)
            .map(|(c, label)| CellState {
                run_id: c.run_id.clone(),
                ..CellState::unassigned(label.clone())
            })
            .collect(),
    };
    store.save_campaign(&downgraded).unwrap();
    assert_eq!(store.load_campaign("legacy").unwrap().schema_version, 1);

    // phase 3: bare resume from the stored spec, the `campaign run
    // --name legacy` path — migrates, then continues from checkpoints
    let stored = store.load_campaign("legacy").unwrap();
    let resumed_cfg = CampaignCfg::from_spec_json("legacy", &stored.spec).unwrap();
    let out = run_campaign(&store, &resumed_cfg).unwrap();
    assert!(out.complete(), "{out:?}");

    // the manifest is upgraded in place: v2, overlay labels, same runs
    let migrated = store.load_campaign("legacy").unwrap();
    assert_eq!(
        migrated.schema_version,
        fedel::store::schema::CAMPAIGN_SCHEMA_VERSION
    );
    let labels: Vec<&str> = migrated.cells.iter().map(|c| c.label.as_str()).collect();
    assert_eq!(
        labels,
        four_axis_grid("legacy")
            .cells()
            .unwrap()
            .iter()
            .map(CampaignCell::label)
            .collect::<Vec<_>>()
    );
    for (old, new) in m2.cells.iter().zip(&migrated.cells) {
        assert_eq!(old.run_id, new.run_id, "run assignments must survive migration");
    }
    assert!(migrated.spec.get("strategies").is_none(), "spec upgraded to axes form");

    assert_stores_identical(&reference, &store, "legacy");
    let _ = std::fs::remove_dir_all(&reference_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The dead-worker drill (operator acceptance): two operate workers share
/// one served store; a third worker "died" mid-cell holding a lease
/// (simulated by the stale heartbeat it left in the manifest). A survivor
/// reclaims the expired lease, resumes the cell from its checkpoint, and
/// the finished store is bitwise-identical to a single-process reference.
#[test]
fn dead_workers_lease_is_reclaimed_and_results_match_reference_bitwise() {
    use fedel::operator::{operate, OperateCfg};
    use fedel::store::backend::serve::StoreServer;

    let reference_dir = scratch("lease-ref");
    let reference = RunStore::open(&reference_dir).unwrap();
    assert!(run_campaign(&reference, &grid("sweep")).unwrap().complete());

    let dir = scratch("lease-served");
    let server = StoreServer::start(&dir, "127.0.0.1:0", 4).unwrap();
    let url = format!("http://{}", server.addr());
    let store = RunStore::open(&url).unwrap();

    // the doomed worker advanced every cell to its round-2 checkpoint,
    // then died still holding the lease on the first cell
    let mut phase1 = grid("sweep");
    phase1.halt_after = Some(3);
    assert!(!run_campaign(&store, &phase1).unwrap().complete());
    store
        .update_campaign("sweep", |mut m| {
            m.cells[0].worker = Some("w-dead".into());
            m.cells[0].lease_unix = 1; // last heartbeat eons ago
            Ok(m)
        })
        .unwrap();

    // two surviving workers reconcile the same campaign concurrently,
    // each through its own HTTP client
    let outs: Vec<fedel::operator::OperateOutcome> = std::thread::scope(|scope| {
        let handles: Vec<_> = ["w-live-1", "w-live-2"]
            .into_iter()
            .map(|w| {
                let url = url.clone();
                scope.spawn(move || {
                    let store = RunStore::open(&url).unwrap();
                    let mut ocfg = OperateCfg::new("sweep");
                    ocfg.worker = w.into();
                    ocfg.lease_secs = 3600;
                    ocfg.poll_secs = 1;
                    operate(&store, &ocfg, None).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert!(outs.iter().all(|o| o.converged), "{outs:?}");
    let reclaimed: usize = outs.iter().map(|o| o.reclaimed).sum();
    assert!(reclaimed >= 1, "the stale lease was never reclaimed: {outs:?}");
    let completed: usize = outs.iter().map(|o| o.completed).sum();
    assert_eq!(completed, 4, "{outs:?}");

    // every lease released, and the bytes match the reference exactly
    let m = store.load_campaign("sweep").unwrap();
    assert!(m.cells.iter().all(|c| c.worker.is_none()), "{m:?}");
    assert_stores_identical(&reference, &store, "sweep");
    server.shutdown();
    let _ = std::fs::remove_dir_all(&reference_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The adaptive-sweep acceptance drill: a successive-halving campaign
/// seeded with two cells, live-edited (`seed=+3`) to three, then driven
/// by two concurrent operate workers, must make the same prune decision
/// — and leave bitwise-identical bytes, the loser's rung-truncated run
/// included — as one worker on the full grid from the start.
#[test]
fn live_edited_halving_sweep_prunes_deterministically_vs_reference() {
    use fedel::operator::{edit_campaign, operate, OperateCfg};

    fn halving_grid(name: &str, seeds: &str) -> CampaignCfg {
        let base = ExperimentCfg {
            model: "mock:4x20".into(),
            fleet: fedel::config::FleetSpec::Scales(vec![1.0, 2.0]),
            rounds: 4,
            local_steps: 2,
            lr: 0.3,
            eval_every: 2,
            eval_batches: 2,
            slowest_round_secs: 3600.0,
            exec_threads: 1,
            ..Default::default()
        };
        let mut cfg = CampaignCfg::new(name, base);
        cfg.axis(&format!("seed={seeds}")).unwrap();
        cfg.set = fedel::config::params::SpecOverlay::parse(
            fedel::config::params::ParamSpace::shared(),
            &["operator.halving.rungs=1"],
        )
        .unwrap();
        cfg.checkpoint_every = 2;
        cfg
    }
    fn worker(name: &str, w: &str) -> OperateCfg {
        let mut ocfg = OperateCfg::new(name);
        ocfg.worker = w.into();
        ocfg.lease_secs = 3600;
        ocfg.poll_secs = 1;
        ocfg
    }

    // reference: the final grid from the start, one worker
    let reference_dir = scratch("halve-ref");
    let reference = RunStore::open(&reference_dir).unwrap();
    let out = operate(&reference, &worker("halve", "w-ref"), Some(&halving_grid("halve", "1,2,3")))
        .unwrap();
    assert!(out.converged, "{out:?}");
    assert_eq!(out.pruned, 1, "keep = ceil(0.5 * 3) = 2 of 3: {out:?}");
    assert_eq!(out.completed, 2, "{out:?}");

    // live path: seed the two-cell grid (max_segments = 0 registers the
    // campaign without running anything), append seed=3 mid-flight, then
    // converge with two workers sharing the local store
    let dir = scratch("halve-live");
    let store = RunStore::open(&dir).unwrap();
    let mut register = worker("halve", "w-0");
    register.max_segments = Some(0);
    let out = operate(&store, &register, Some(&halving_grid("halve", "1,2"))).unwrap();
    assert!(!out.converged);
    assert_eq!(out.segments, 0);
    let edited = edit_campaign(&store, "halve", &["seed=+3".to_string()]).unwrap();
    assert_eq!(edited.cells.len(), 3);
    let outs: Vec<fedel::operator::OperateOutcome> = std::thread::scope(|scope| {
        let handles: Vec<_> = ["w-a", "w-b"]
            .into_iter()
            .map(|w| {
                let store = &store;
                scope.spawn(move || operate(store, &worker("halve", w), None).unwrap())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert!(outs.iter().all(|o| o.converged), "{outs:?}");
    assert_eq!(outs.iter().map(|o| o.completed).sum::<usize>(), 2, "{outs:?}");
    assert_eq!(outs.iter().map(|o| o.pruned).sum::<usize>(), 1, "{outs:?}");

    // identical decisions and identical bytes, cell by cell: the same
    // seed loses at the same rung with the same truncated record set,
    // and the survivors' complete runs match down to the final params
    let ma = reference.load_campaign("halve").unwrap();
    let mb = store.load_campaign("halve").unwrap();
    assert_eq!(
        ma.cells.iter().map(|c| &c.label).collect::<Vec<_>>(),
        mb.cells.iter().map(|c| &c.label).collect::<Vec<_>>()
    );
    for (ca, cb) in ma.cells.iter().zip(&mb.cells) {
        assert_eq!(ca.pruned, cb.pruned, "{}: prune decision diverged", ca.label);
        let ra = reference.load_manifest(ca.run_id.as_ref().unwrap()).unwrap();
        let rb = store.load_manifest(cb.run_id.as_ref().unwrap()).unwrap();
        assert_eq!(ra.records.len(), rb.records.len(), "{}", ca.label);
        if ca.pruned {
            assert_eq!(ra.records.len(), 2, "{}: loser stops at its rung", ca.label);
        }
        for (x, y) in ra.records.iter().zip(&rb.records) {
            assert_eq!(x.sim_time.to_bits(), y.sim_time.to_bits(), "{}", ca.label);
            assert_eq!(
                x.mean_train_loss.to_bits(),
                y.mean_train_loss.to_bits(),
                "{}",
                ca.label
            );
            assert_eq!(x.eval_acc.map(f64::to_bits), y.eval_acc.map(f64::to_bits), "{}", ca.label);
        }
        if !ca.pruned {
            assert_eq!(ra.status, RunStatus::Complete, "{}", ca.label);
            assert_eq!(rb.status, RunStatus::Complete, "{}", ca.label);
            let fa = ra.final_state.as_ref().unwrap();
            let fb = rb.final_state.as_ref().unwrap();
            assert_eq!(
                reference.get_params(&fa.params).unwrap(),
                store.get_params(&fb.params).unwrap(),
                "{}: final params diverged",
                ca.label
            );
        }
    }
    let _ = std::fs::remove_dir_all(&reference_dir);
    let _ = std::fs::remove_dir_all(&dir);
}
