//! Campaign crash safety: a grid killed mid-flight — at the campaign
//! level (workers stop claiming cells) and at the cell level
//! (`halt_after` kills rounds between checkpoints) — resumes to
//! completion with previously-finished cells skipped, and every cell's
//! stored records and parameters bitwise-identical to an uninterrupted
//! campaign's. Extends `tests/resume.rs`' invariant from one run to whole
//! grids.

use std::path::PathBuf;

use fedel::config::ExperimentCfg;
use fedel::sim::campaign::{report, run_campaign, CampaignCfg, CellRun};
use fedel::store::schema::{RunManifest, RunStatus};
use fedel::store::RunStore;

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fedel-campaign-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// 2 strategies x 2 seeds on the mock engine, one worker so the
/// campaign-level kill lands on a deterministic cell boundary.
fn grid(name: &str) -> CampaignCfg {
    let base = ExperimentCfg {
        model: "mock:6x50".into(),
        fleet: fedel::config::FleetSpec::Scales(vec![1.0, 2.0, 4.0]),
        rounds: 6,
        local_steps: 2,
        lr: 0.3,
        eval_every: 2,
        eval_batches: 2,
        slowest_round_secs: 3600.0,
        exec_threads: 1,
        ..Default::default()
    };
    let mut cfg = CampaignCfg::new(name, base);
    cfg.strategies = vec!["fedavg".into(), "fedel".into()];
    cfg.seeds = vec![1, 2];
    cfg.checkpoint_every = 2;
    cfg.workers = 1;
    cfg
}

/// The stored run behind each cell label, via the campaign manifest.
fn cell_runs(store: &RunStore, name: &str) -> Vec<(String, RunManifest)> {
    let m = store.load_campaign(name).unwrap();
    m.cells
        .iter()
        .map(|c| {
            let id = c.run_id.as_ref().unwrap_or_else(|| panic!("cell {} unassigned", c.label));
            (c.label.clone(), store.load_manifest(id).unwrap())
        })
        .collect()
}

fn assert_stores_identical(a: &RunStore, b: &RunStore, name: &str) {
    let runs_a = cell_runs(a, name);
    let runs_b = cell_runs(b, name);
    assert_eq!(runs_a.len(), runs_b.len());
    for ((label_a, ma), (label_b, mb)) in runs_a.iter().zip(&runs_b) {
        assert_eq!(label_a, label_b);
        assert_eq!(ma.status, RunStatus::Complete, "{label_a}");
        assert_eq!(mb.status, RunStatus::Complete, "{label_a}");
        assert_eq!(ma.records.len(), mb.records.len(), "{label_a}: record count");
        for (ra, rb) in ma.records.iter().zip(&mb.records) {
            assert_eq!(ra.round, rb.round, "{label_a}");
            assert_eq!(
                ra.sim_time.to_bits(),
                rb.sim_time.to_bits(),
                "{label_a}: round {} clock",
                ra.round
            );
            assert_eq!(
                ra.mean_train_loss.to_bits(),
                rb.mean_train_loss.to_bits(),
                "{label_a}: round {} loss",
                ra.round
            );
            assert_eq!(
                ra.eval_acc.map(f64::to_bits),
                rb.eval_acc.map(f64::to_bits),
                "{label_a}: round {} eval",
                ra.round
            );
        }
        let fa = ma.final_state.as_ref().unwrap();
        let fb = mb.final_state.as_ref().unwrap();
        assert_eq!(fa.final_acc.to_bits(), fb.final_acc.to_bits(), "{label_a}");
        assert_eq!(
            a.get_params(&fa.params).unwrap(),
            b.get_params(&fb.params).unwrap(),
            "{label_a}: final params diverged"
        );
    }
}

#[test]
fn campaign_runs_grid_reports_and_is_idempotent() {
    let dir = scratch("idempotent");
    let store = RunStore::open(&dir).unwrap();
    let cfg = grid("sweep");

    let outcome = run_campaign(&store, &cfg).unwrap();
    assert!(outcome.complete(), "{outcome:?}");
    assert!(outcome.cells.iter().all(|c| c.status == CellRun::Completed));
    assert_eq!(outcome.cells.len(), 4);

    // every cell's run is stored and complete
    for (label, m) in cell_runs(&store, "sweep") {
        assert_eq!(m.status, RunStatus::Complete, "{label}");
        assert_eq!(m.records.len(), 6, "{label}");
    }

    // the whole-grid report defaults its baseline to the fedavg cell
    let man = store.load_campaign("sweep").unwrap();
    let rep = report(&store, &man, None, None).unwrap();
    assert_eq!(rep.rows.len(), 4);
    assert_eq!(rep.baseline, man.cells[0].run_id.clone().unwrap());
    // an explicit strategy baseline resolves too
    let rep = report(&store, &man, None, Some("fedel")).unwrap();
    assert!(rep.baseline.starts_with("fedel"));

    // running the finished campaign again touches nothing
    let again = run_campaign(&store, &cfg).unwrap();
    assert!(again.complete());
    assert!(again.cells.iter().all(|c| c.status == CellRun::Skipped), "{again:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The acceptance drill: kill the campaign after two cells, then kill the
/// remaining cells mid-round via `halt_after`, then resume everything —
/// completed cells skipped, killed cells continued from their
/// checkpoints, results bitwise-identical to a never-interrupted campaign.
#[test]
fn killed_campaign_resumes_skipping_completed_cells_bitwise_identically() {
    let reference_dir = scratch("reference");
    let reference = RunStore::open(&reference_dir).unwrap();
    let uninterrupted = run_campaign(&reference, &grid("sweep")).unwrap();
    assert!(uninterrupted.complete());

    let dir = scratch("killed");
    let store = RunStore::open(&dir).unwrap();

    // phase 1: the campaign process dies after two cells finished
    let mut phase1 = grid("sweep");
    phase1.halt_after_cells = Some(2);
    let out = run_campaign(&store, &phase1).unwrap();
    assert!(out.halted);
    // (skipped, completed, failed, pending)
    assert_eq!(out.counts(), (0, 2, 0, 2), "{out:?}");

    // phase 2: the remaining cells get killed *inside* a round span —
    // after round 3, between the round-2 and round-4 checkpoints
    let mut phase2 = grid("sweep");
    phase2.halt_after = Some(3);
    let out = run_campaign(&store, &phase2).unwrap();
    assert!(!out.complete());
    assert_eq!(out.counts(), (2, 0, 2, 0), "{out:?}");
    for c in out.failures() {
        match &c.status {
            CellRun::Failed(msg) => assert!(msg.contains("halted"), "{msg}"),
            other => panic!("{other:?}"),
        }
    }
    // what the kill left on disk: checkpoints at round 2, 2 records
    let man = store.load_campaign("sweep").unwrap();
    for cell in &man.cells[2..] {
        let run = store.load_manifest(cell.run_id.as_ref().unwrap()).unwrap();
        assert_eq!(run.status, RunStatus::Running, "{}", cell.label);
        assert_eq!(run.checkpoint.as_ref().unwrap().completed, 2, "{}", cell.label);
        assert_eq!(run.records.len(), 2, "{}", cell.label);
    }

    // phase 3: plain resume — completed cells skipped, killed cells
    // continued from their checkpoints to completion
    let out = run_campaign(&store, &grid("sweep")).unwrap();
    assert!(out.complete(), "{out:?}");
    assert_eq!(out.counts(), (2, 2, 0, 0), "{out:?}");

    assert_stores_identical(&reference, &store, "sweep");
    let _ = std::fs::remove_dir_all(&reference_dir);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn same_name_different_grid_is_rejected() {
    let dir = scratch("mismatch");
    let store = RunStore::open(&dir).unwrap();
    let mut small = grid("sweep");
    small.halt_after_cells = Some(1);
    run_campaign(&store, &small).unwrap();

    let mut other = grid("sweep");
    other.seeds = vec![7, 8];
    let err = run_campaign(&store, &other).unwrap_err();
    assert!(err.to_string().contains("different grid"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}
