//! Million-client lazy fleets: the scale claim behind `FleetSpec::Lazy`.
//!
//! A lazily-materialized fleet keeps O(device types) state — timing
//! models, device profiles — and derives everything per-client (profile,
//! dataset shard) on demand from the seed. These tests are the
//! allocation guard: building and running a 1M-client experiment must
//! not materialize per-client vectors for clients that were never
//! sampled.

use fedel::config::{ExperimentCfg, FleetSpec};
use fedel::fleet::FleetView;
use fedel::sim::experiment::{run_one, Experiment};

fn lazy_cfg(threads: usize) -> ExperimentCfg {
    ExperimentCfg {
        model: "mock:6x50".into(),
        strategy: "fedasync".into(),
        fleet: FleetSpec::parse("lazy1000000:lognormal:0:0.5").unwrap(),
        fleet_sample: 4,
        rounds: 3,
        local_steps: 4,
        lr: 0.3,
        eval_every: 2,
        eval_batches: 2,
        slowest_round_secs: 3600.0,
        exec_threads: threads,
        ..Default::default()
    }
}

#[test]
fn million_client_fleet_builds_without_per_client_state() {
    let exp = Experiment::build(lazy_cfg(1)).unwrap();
    assert_eq!(exp.ctx.n_clients(), 1_000_000);
    assert_eq!(exp.dataset.n_clients(), 1_000_000);
    // the allocation guard proper: no per-client vectors anywhere
    assert!(
        exp.dataset.clients.is_empty(),
        "lazy dataset materialized {} per-client entries",
        exp.dataset.clients.len()
    );
    assert!(
        exp.ctx.timings.len() <= 32,
        "lazy fleet should carry one timing model per device type, got {}",
        exp.ctx.timings.len()
    );
    assert!(exp.fleet.len() <= 32, "device-type table, not a client table");

    // profiles and shards derive on demand, pure in the client id
    let lf = exp.ctx.fleet.lazy.as_ref().expect("lazy fleet info");
    assert_eq!(lf.len(), 1_000_000);
    let p = lf.profile(999_999);
    assert!(p.device.scale > 0.0);
    assert_eq!(p, lf.profile(999_999), "profile derivation must be pure");
    let shard = exp.dataset.client(999_999);
    assert_eq!(shard.id, 999_999);
    assert_eq!(shard.num_samples, exp.dataset.client(999_999).num_samples);
}

#[test]
fn million_client_async_run_completes_under_sampling_and_churn() {
    let run = |threads: usize| {
        let mut c = lazy_cfg(threads);
        c.churn_dropout = 0.2;
        run_one(c).unwrap()
    };
    let seq = run(1);
    assert_eq!(seq.records.len(), 3, "one record per aggregation");
    assert!(seq.records.iter().all(|r| r.participants >= 1));
    // at most `fleet.sample` clients ever hold state at once, so no
    // aggregation can report more participants than the in-flight cap
    assert!(seq.records.iter().all(|r| r.participants <= 4));
    // the scale invariants hold under parallel execution too
    let par = run(3);
    assert_eq!(seq.final_params, par.final_params, "lazy-fleet run diverged across threads");
    assert_eq!(seq.sim_total_secs.to_bits(), par.sim_total_secs.to_bits());
}
