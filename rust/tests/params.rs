//! Property tests for the typed parameter space: overlays round-trip
//! through manifest JSON exactly, and overlay precedence (base < axis <
//! `--set`) is order-independent within a layer.

use fedel::config::params::{Binding, ParamSpace, ParamValue, SpecOverlay, SweepAxis};
use fedel::config::{ExperimentCfg, FleetSpec};
use fedel::util::json::Json;
use fedel::util::prop::{check, no_shrink, shrink_vec};
use fedel::util::rng::Rng;

/// A random typed value for a registered key.
fn random_value(rng: &mut Rng, key: &str) -> ParamValue {
    match key {
        "model" => ParamValue::Str(format!("mock:{}x{}", 1 + rng.below(8), 1 + rng.below(200))),
        "strategy" => {
            let names = ["fedavg", "fedel", "timelyfl", "pyramidfl", "heterofl"];
            ParamValue::Str(names[rng.below(names.len())].to_string())
        }
        "fleet" => match rng.below(3) {
            0 => ParamValue::Fleet(FleetSpec::Small10),
            1 => ParamValue::Fleet(FleetSpec::Large(1 + rng.below(200))),
            _ => ParamValue::Fleet(FleetSpec::Scales(
                (0..1 + rng.below(4)).map(|_| (1 + rng.below(8)) as f64 / 2.0).collect(),
            )),
        },
        "seed" => ParamValue::U64(rng.next_u64()),
        "train.rounds" | "train.local_steps" | "eval.every" | "eval.batches" => {
            ParamValue::Usize(1 + rng.below(64))
        }
        // Positive floats with awkward mantissas: exactness is the point.
        "train.lr" | "data.alpha" | "time.t_th_factor" => {
            ParamValue::F64(rng.f64().max(f64::MIN_POSITIVE) * 3.0f64.powi(rng.below(5) as i32))
        }
        "time.comm_secs" | "time.slowest_round_secs" => ParamValue::F64(rng.f64() * 1e4),
        "comm.up_mbps" | "comm.down_mbps" => ParamValue::F64(rng.f64() * 1e3),
        "comm.latency_secs" => ParamValue::F64(rng.f64()),
        "strategy.fedbuff.buffer_k" => ParamValue::F64((1 + rng.below(16)) as f64),
        // strategy.<s>.<p> keys: [0.05, 0.9] sits inside every declared
        // bound in the registry (tightest: deadline_frac >= 0.05,
        // explore <= 0.99), while still exercising awkward mantissas.
        _ => ParamValue::F64(0.05 + rng.f64() * 0.85),
    }
}

/// A random overlay: a distinct-key subset of the registered space.
fn random_overlay(rng: &mut Rng) -> Vec<Binding> {
    let space = ParamSpace::shared();
    let nkeys = space.keys().len();
    let picks = 1 + rng.below(nkeys.min(8));
    let mut idxs = rng.choose_k(nkeys, picks);
    idxs.sort();
    idxs.iter()
        .map(|&i| {
            let key = space.keys()[i].key.clone();
            let value = random_value(rng, &key);
            Binding { key, value }
        })
        .collect()
}

#[test]
fn prop_overlay_round_trips_through_manifest_json() {
    check(
        "overlay json round-trip",
        200,
        random_overlay,
        |bindings| {
            let space = ParamSpace::shared();
            let mut overlay = SpecOverlay::new();
            for b in bindings {
                overlay.push(b.clone()).map_err(|e| e.to_string())?;
            }
            // through text, exactly as campaigns/<name>.json stores it
            let text = overlay.to_json().to_string_pretty();
            let back = SpecOverlay::from_json(space, &Json::parse(&text).map_err(|e| e.to_string())?)
                .map_err(|e| e.to_string())?;
            if back != overlay {
                return Err(format!("{back:?} != {overlay:?}"));
            }
            // and the applied configs agree bitwise (render/parse is exact)
            let mut a = ExperimentCfg::default();
            let mut b = ExperimentCfg::default();
            overlay.apply(space, &mut a).map_err(|e| e.to_string())?;
            back.apply(space, &mut b).map_err(|e| e.to_string())?;
            let (ja, jb) = (a.to_json().to_string_pretty(), b.to_json().to_string_pretty());
            if ja != jb {
                return Err(format!("configs diverged:\n{ja}\n---\n{jb}"));
            }
            Ok(())
        },
        shrink_vec,
    );
}

#[test]
fn prop_overlay_precedence_is_order_independent_within_layers() {
    // (axis layer, set layer, shuffle seed): applying base -> axis -> set
    // must resolve identically under any permutation *within* each layer,
    // and set-layer bindings must win over axis bindings for shared keys.
    let gen = |rng: &mut Rng| {
        let axis = random_overlay(rng);
        let mut set = random_overlay(rng);
        // make overlap likely: retag half the axis keys into the set layer
        for b in axis.iter().take(axis.len() / 2) {
            if !set.iter().any(|s| s.key == b.key) {
                set.push(Binding { key: b.key.clone(), value: random_value(rng, &b.key) });
            }
        }
        (axis, set, rng.next_u64())
    };
    check(
        "overlay precedence",
        120,
        gen,
        |(axis, set, shuffle_seed)| {
            let space = ParamSpace::shared();
            let resolve = |axis: &[Binding], set: &[Binding]| -> Result<String, String> {
                let mut cfg = ExperimentCfg::default();
                for layer in [axis, set] {
                    let mut overlay = SpecOverlay::new();
                    for b in layer {
                        overlay.push(b.clone()).map_err(|e| e.to_string())?;
                    }
                    overlay.apply(space, &mut cfg).map_err(|e| e.to_string())?;
                }
                Ok(cfg.to_json().to_string_pretty())
            };
            let reference = resolve(axis, set)?;
            let mut rng = Rng::new(*shuffle_seed);
            for _ in 0..4 {
                let (mut a, mut s) = (axis.clone(), set.clone());
                rng.shuffle(&mut a);
                rng.shuffle(&mut s);
                let shuffled = resolve(&a, &s)?;
                if shuffled != reference {
                    return Err(format!(
                        "layer-internal order changed the resolved config:\n{reference}\n---\n{shuffled}"
                    ));
                }
            }
            // the set layer wins on every shared key
            let mut cfg = ExperimentCfg::default();
            let mut overlay = SpecOverlay::new();
            for b in axis {
                overlay.push(b.clone()).map_err(|e| e.to_string())?;
            }
            overlay.apply(space, &mut cfg).map_err(|e| e.to_string())?;
            let mut set_overlay = SpecOverlay::new();
            for b in set {
                set_overlay.push(b.clone()).map_err(|e| e.to_string())?;
            }
            set_overlay.apply(space, &mut cfg).map_err(|e| e.to_string())?;
            for b in set {
                let def = space.resolve(&b.key).map_err(|e| e.to_string())?;
                if def.get(&cfg) != b.value {
                    return Err(format!("set binding {} lost to the axis layer", b.render()));
                }
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn sweep_axis_values_round_trip_through_spec_json() {
    let space = ParamSpace::shared();
    check(
        "axis json round-trip",
        100,
        |rng: &mut Rng| {
            let keys = ["seed", "data.alpha", "train.lr", "strategy.fedel.harmonize_weight"];
            let key = keys[rng.below(keys.len())];
            let mut values = Vec::new();
            for _ in 0..1 + rng.below(5) {
                let v = random_value(rng, key);
                if !values.contains(&v) {
                    values.push(v);
                }
            }
            SweepAxis { key: key.to_string(), values }
        },
        |axis| {
            let text = axis.to_json().to_string_pretty();
            let back = SweepAxis::from_json(space, &Json::parse(&text).map_err(|e| e.to_string())?)
                .map_err(|e| e.to_string())?;
            if back != *axis {
                return Err(format!("{back:?} != {axis:?}"));
            }
            Ok(())
        },
        no_shrink,
    );
}
