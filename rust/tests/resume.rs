//! Fault tolerance: a run killed mid-flight and resumed from the store is
//! bitwise-identical to one that was never interrupted — extending
//! `tests/determinism.rs`' invariant across process boundaries. The kill
//! lands *between* checkpoints on purpose, so every resume recomputes at
//! least one round from the stored global params + policy (+ RNG) state.

use std::path::PathBuf;

use fedel::config::{ExperimentCfg, FleetSpec};
use fedel::fl::observer::NullObserver;
use fedel::fl::server::{ExperimentResult, ResumeState};
use fedel::sim::experiment::{resume_run, Experiment};
use fedel::store::checkpoint::CheckpointObserver;
use fedel::store::schema::RunStatus;
use fedel::store::RunStore;

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fedel-resume-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn cfg(strategy: &str, threads: usize) -> ExperimentCfg {
    ExperimentCfg {
        model: "mock:6x50".into(),
        strategy: strategy.into(),
        fleet: FleetSpec::Scales(vec![1.0, 1.5, 2.0, 2.5, 3.0, 4.0]),
        rounds: 8,
        local_steps: 4,
        lr: 0.3,
        eval_every: 2,
        eval_batches: 2,
        slowest_round_secs: 3600.0,
        exec_threads: threads,
        ..Default::default()
    }
}

fn assert_identical(a: &ExperimentResult, b: &ExperimentResult, label: &str) {
    assert_eq!(a.final_params, b.final_params, "{label}: global params diverged");
    assert_eq!(a.final_acc.to_bits(), b.final_acc.to_bits(), "{label}: final_acc");
    assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits(), "{label}: final_loss");
    assert_eq!(
        a.sim_total_secs.to_bits(),
        b.sim_total_secs.to_bits(),
        "{label}: sim_total_secs"
    );
    assert_eq!(a.records.len(), b.records.len(), "{label}: record count");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.round, rb.round, "{label}: round index");
        assert_eq!(
            ra.round_secs.to_bits(),
            rb.round_secs.to_bits(),
            "{label}: round {} secs",
            ra.round
        );
        assert_eq!(
            ra.mean_train_loss.to_bits(),
            rb.mean_train_loss.to_bits(),
            "{label}: round {} loss",
            ra.round
        );
        assert_eq!(
            ra.sim_time.to_bits(),
            rb.sim_time.to_bits(),
            "{label}: round {} clock",
            ra.round
        );
        assert_eq!(ra.o1.to_bits(), rb.o1.to_bits(), "{label}: round {} o1", ra.round);
        assert_eq!(ra.mean_coverage.to_bits(), rb.mean_coverage.to_bits(), "{label}");
        assert_eq!(ra.participants, rb.participants, "{label}");
        assert_eq!(
            ra.eval_acc.map(f64::to_bits),
            rb.eval_acc.map(f64::to_bits),
            "{label}: round {} eval",
            ra.round
        );
        assert_eq!(
            ra.eval_loss.map(f64::to_bits),
            rb.eval_loss.map(f64::to_bits),
            "{label}: round {} eval loss",
            ra.round
        );
        assert_eq!(ra.client_secs, rb.client_secs, "{label}: round {} clients", ra.round);
        assert_eq!(ra.dropped, rb.dropped, "{label}: round {} drops", ra.round);
        assert_eq!(ra.spec_hits, rb.spec_hits, "{label}: round {} spec hits", ra.round);
        assert_eq!(ra.spec_misses, rb.spec_misses, "{label}: round {} spec misses", ra.round);
    }
}

/// Kill a checkpointed run after round 5 (checkpoints land at 2 and 4),
/// resume it, and demand bitwise identity with an uninterrupted run.
fn kill_and_resume(strategy: &str, kill_threads: usize, resume_threads: usize) {
    kill_and_resume_with(strategy, kill_threads, resume_threads, "plain", &|_| {});
}

/// Same drill with a scenario knob: `mutate` is applied identically to
/// the baseline and the killed run (churn, lazy fleets, sampling, ...).
fn kill_and_resume_with(
    strategy: &str,
    kill_threads: usize,
    resume_threads: usize,
    tag: &str,
    mutate: &dyn Fn(&mut ExperimentCfg),
) {
    let label = format!("{strategy}/{tag} killed@{kill_threads}t resumed@{resume_threads}t");
    let dir = scratch(&format!("{strategy}-{tag}-{kill_threads}-{resume_threads}"));
    let store = RunStore::open(&dir).unwrap();

    let mut base_cfg = cfg(strategy, resume_threads);
    mutate(&mut base_cfg);
    let baseline = Experiment::build(base_cfg).unwrap().run(None).unwrap();

    let mut killed_cfg = cfg(strategy, kill_threads);
    mutate(&mut killed_cfg);
    killed_cfg.halt_after = Some(5);
    let mut exp = Experiment::build(killed_cfg).unwrap();
    let mut ckpt = CheckpointObserver::create(&store, &exp.cfg, strategy, 2).unwrap();
    let id = ckpt.run_id().to_string();
    let err = exp.run_from(None, &mut ckpt, None).unwrap_err();
    assert!(err.to_string().contains("halted"), "{err}");
    assert!(ckpt.take_error().is_none(), "{label}: checkpointing failed");

    // What a crashed process leaves on disk: the round-4 checkpoint and
    // exactly 4 records (round 5 happened but was never persisted).
    let man = store.load_manifest(&id).unwrap();
    assert_eq!(man.status, RunStatus::Running, "{label}");
    assert_eq!(man.checkpoint.as_ref().unwrap().completed, 4, "{label}");
    assert_eq!(man.records.len(), 4, "{label}");

    let resumed = resume_run(&store, &id, 2, &mut NullObserver).unwrap();
    assert_identical(&baseline, &resumed, &label);

    let man = store.load_manifest(&id).unwrap();
    assert_eq!(man.status, RunStatus::Complete, "{label}");
    assert_eq!(man.records.len(), 8, "{label}");
    let fin = man.final_state.as_ref().unwrap();
    assert_eq!(fin.final_acc.to_bits(), baseline.final_acc.to_bits(), "{label}");
    assert_eq!(
        store.get_params(&fin.params).unwrap(),
        baseline.final_params,
        "{label}: stored final params"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fedel_kill_and_resume_is_bitwise_identical() {
    kill_and_resume("fedel", 1, 1);
}

#[test]
fn resume_is_identical_across_thread_counts() {
    // Kill under one executor config, resume under another: the store's
    // state is thread-count-agnostic, like everything else.
    kill_and_resume("fedel", 2, 1);
    kill_and_resume("fedel", 1, 2);
    kill_and_resume("fedel", 0, 2);
}

#[test]
fn stateless_and_rng_strategies_survive_resume() {
    // fedavg: no policy state at all; pyramidfl: client-selection RNG must
    // continue bit-for-bit; elastictrainer: per-client importance state.
    for strategy in ["fedavg", "pyramidfl", "elastictrainer"] {
        kill_and_resume(strategy, 1, 1);
    }
}

/// The async executor's kill/resume drill: fedbuff's in-flight client
/// clocks, dispatch versions, and staleness buffer ride the checkpoint's
/// `async_state`, so a run killed between checkpoints (after aggregation
/// 5, checkpoint at 4) resumes bitwise-identically — including across
/// different thread counts on either side of the kill.
#[test]
fn fedbuff_kill_and_resume_is_bitwise_identical() {
    kill_and_resume("fedbuff", 1, 1);
    kill_and_resume("fedbuff", 4, 1);
    kill_and_resume("fedbuff", 1, 4);
}

#[test]
fn fedasync_kill_and_resume_is_bitwise_identical() {
    kill_and_resume("fedasync", 1, 1);
}

/// Availability churn across a kill: the drop decisions are pure hashes
/// of (seed, client, iter/time), so a churned run killed mid-flight
/// resumes onto exactly the same drop/aggregate sequence — at any thread
/// count on either side of the kill. Both async modes recompute each
/// in-flight dispatch's doom verdict from the checkpoint instead of
/// persisting it.
#[test]
fn churned_async_kill_and_resume_is_bitwise_identical() {
    let churn = |c: &mut ExperimentCfg| {
        c.churn_dropout = 0.5;
        c.churn_period_secs = 4000.0;
        c.churn_avail_frac = 0.75;
    };
    kill_and_resume_with("fedbuff", 1, 1, "churn", &churn);
    kill_and_resume_with("fedbuff", 4, 1, "churn", &churn);
    kill_and_resume_with("fedasync", 1, 4, "churn", &churn);
}

/// Speculative dispatch across a kill: the `speculated` version bindings
/// ride the checkpoint's `async_state` and the hit/miss counters ride the
/// persisted round records, so a speculative run killed mid-flight
/// resumes bitwise — counters included (`assert_identical` compares them,
/// and the pre-kill rounds come back through the store's schema) — at any
/// thread count on either side of the kill. Speculations pending on the
/// worker pool at the kill simply re-execute on resume: the bindings are
/// state, the outcome cache is not.
#[test]
fn speculative_kill_and_resume_is_bitwise_identical() {
    let spec = |c: &mut ExperimentCfg| c.exec_speculate_depth = 4;
    kill_and_resume_with("fedbuff", 2, 1, "spec", &spec);
    kill_and_resume_with("fedbuff", 1, 4, "spec", &spec);
    kill_and_resume_with("fedasync", 1, 2, "spec", &spec);
    // doom-at-validate must survive the kill too: churned speculation
    // resumes onto the same hit/miss/drop sequence
    kill_and_resume_with("fedbuff", 2, 2, "spec-churn", &|c| {
        c.exec_speculate_depth = 4;
        c.churn_dropout = 0.5;
        c.churn_period_secs = 4000.0;
        c.churn_avail_frac = 0.75;
    });
}

/// Sync-mode churn rides the per-round records (`dropped`), which the
/// resumed run must reproduce bitwise from the checkpoint.
#[test]
fn churned_sync_kill_and_resume_is_bitwise_identical() {
    kill_and_resume_with("fedel", 1, 2, "churn", &|c| c.churn_dropout = 0.4);
}

/// Lazy generated fleet + in-flight sampling + churn, killed and
/// resumed: the manifest's config snapshot (generator spec, sample cap,
/// churn keys) plus the async runner state is everything resume needs —
/// client profiles and datasets re-derive on demand from the seed.
#[test]
fn lazy_sampled_fleet_kill_and_resume_is_bitwise_identical() {
    let lazy = |c: &mut ExperimentCfg| {
        c.fleet = FleetSpec::parse("lazy64:lognormal:0:0.5").unwrap();
        c.fleet_sample = 6;
        c.churn_dropout = 0.3;
    };
    kill_and_resume_with("fedbuff", 1, 2, "lazy", &lazy);
    kill_and_resume_with("fedasync", 2, 1, "lazy", &lazy);
}

/// Schema v3: the parameter vectors inside an async checkpoint's
/// `async_state` (referenced global versions, buffered updates) persist
/// as content-addressed BlobRefs, not inline number arrays. The stored
/// manifest must be more than 10x smaller than the same manifest with
/// those vectors inlined the v2 way.
#[test]
fn async_checkpoint_externalizes_params_and_shrinks_the_manifest() {
    let dir = scratch("async-blobref");
    let store = RunStore::open(&dir).unwrap();
    let mut killed = cfg("fedbuff", 1);
    killed.model = "mock:6x200".into(); // big enough that params dominate
    killed.halt_after = Some(5);
    let mut exp = Experiment::build(killed).unwrap();
    let mut ckpt = CheckpointObserver::create(&store, &exp.cfg, "fedbuff", 2).unwrap();
    let id = ckpt.run_id().to_string();
    let _ = exp.run_from(None, &mut ckpt, None).unwrap_err();
    assert!(ckpt.take_error().is_none());

    let man = store.load_manifest(&id).unwrap();
    let ck = man.checkpoint.as_ref().unwrap();
    let stored_text = ck.async_state.to_string();
    assert!(
        stored_text.contains("\"digest\""),
        "async params should persist as BlobRefs: {stored_text}"
    );

    let stored_len = man.to_json().to_string_pretty().len();
    let mut inlined = man.clone();
    inlined.checkpoint.as_mut().unwrap().async_state =
        fedel::store::checkpoint::inline_async_state(&store, &ck.async_state).unwrap();
    let inlined_len = inlined.to_json().to_string_pretty().len();
    assert!(
        inlined_len > 10 * stored_len,
        "externalizing async params should shrink the manifest >10x \
         (inline {inlined_len} bytes vs stored {stored_len})"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// A synchronous checkpoint must not silently resume through the async
/// runner (and vice versa): the mode is validated, not assumed.
#[test]
fn async_checkpoints_are_not_interchangeable_with_sync_ones() {
    let dir = scratch("mode-mismatch");
    let store = RunStore::open(&dir).unwrap();

    let mut killed = cfg("fedbuff", 1);
    killed.halt_after = Some(5);
    let mut exp = Experiment::build(killed).unwrap();
    let mut ckpt = CheckpointObserver::create(&store, &exp.cfg, "fedbuff", 2).unwrap();
    let id = ckpt.run_id().to_string();
    let _ = exp.run_from(None, &mut ckpt, None).unwrap_err();
    assert!(ckpt.take_error().is_none());

    // the stored checkpoint carries the async runner state...
    let man = store.load_manifest(&id).unwrap();
    let ck = man.checkpoint.as_ref().unwrap();
    assert!(
        !matches!(ck.async_state, fedel::util::json::Json::Null),
        "async checkpoints must persist runner state"
    );

    // ...and resuming it under a synchronous strategy fails loudly
    let resume = fedel::store::checkpoint::resume_state(&store, &man).unwrap();
    let mut exp = Experiment::build(cfg("fedavg", 1)).unwrap();
    let err = exp
        .run_from(Some("fedavg"), &mut NullObserver, Some(resume))
        .unwrap_err();
    assert!(err.to_string().contains("async"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_start_seeds_from_stored_run() {
    let dir = scratch("warm");
    let store = RunStore::open(&dir).unwrap();

    // donor: a completed, stored fedavg run
    let mut exp = Experiment::build(cfg("fedavg", 1)).unwrap();
    let mut ckpt = CheckpointObserver::create(&store, &exp.cfg, "fedavg", 4).unwrap();
    let id = ckpt.run_id().to_string();
    let donor = exp.run_from(None, &mut ckpt, None).unwrap();
    assert!(ckpt.take_error().is_none());

    // stored parameters round-trip bitwise
    let stored = store.latest_params(&id).unwrap();
    assert_eq!(stored, donor.final_params);

    // a warm-started run begins where the donor's model left off: its
    // first eval already sits at donor-final level, far above a cold run
    let mut short = cfg("fedavg", 1);
    short.rounds = 2;
    short.eval_every = 1;
    let warm = Experiment::build(short.clone())
        .unwrap()
        .run_from(None, &mut NullObserver, Some(ResumeState::warm_start(stored)))
        .unwrap();
    let cold = Experiment::build(short)
        .unwrap()
        .run_from(None, &mut NullObserver, None)
        .unwrap();
    let warm_first = warm.records[0].eval_acc.unwrap();
    let cold_first = cold.records[0].eval_acc.unwrap();
    assert!(
        warm_first > cold_first,
        "warm start should begin ahead: warm {warm_first} vs cold {cold_first}"
    );

    // Stateful strategies must warm-start too: the Null policy snapshot
    // means "fresh strategy", not an error.
    for strategy in ["fedel", "pyramidfl", "elastictrainer"] {
        let mut c = cfg(strategy, 1);
        c.rounds = 2;
        let donor_params = store.latest_params(&id).unwrap();
        Experiment::build(c)
            .unwrap()
            .run_from(None, &mut NullObserver, Some(ResumeState::warm_start(donor_params)))
            .unwrap_or_else(|e| panic!("{strategy} warm start failed: {e}"));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Schema v4: checkpoints delta-encode against the previous checkpoint's
/// params. A few contiguous spans of changed elements (the shape masked
/// training produces) must store at least 5x smaller than a full
/// snapshot, chain back to the full base, resolve bitwise, and rebase to
/// a full blob when nearly everything changes.
#[test]
fn delta_checkpoints_shrink_storage_and_resolve_bitwise() {
    use fedel::fl::observer::{RoundObserver, ServerState};
    use fedel::manifest::tests_support::chain_manifest;
    use fedel::store::{MEDIA_PARAMS_DELTA, MEDIA_PARAMS_F32LE};
    use fedel::strategies::{by_name, FleetCtx};
    use fedel::timing::{DeviceProfile, TimingCfg, TimingModel};
    use fedel::util::rng::Rng;

    let dir = scratch("delta-size");
    let store = RunStore::open(&dir).unwrap();
    let m = chain_manifest(4, 1000);
    let n = m.param_count;
    let tm = TimingModel::profile(&m, &DeviceProfile::orin(), &TimingCfg::default());
    let ctx = FleetCtx {
        manifest: m,
        timings: vec![tm],
        t_th: 10.0,
        local_steps: 1,
        lr: 0.1,
        fleet: Default::default(),
    };
    let strategy = by_name("fedavg", &ctx, 0.25, 7).unwrap();

    let mut rng = Rng::new(7);
    let g0: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    // ~5% of elements move, in two contiguous spans (mask-shaped change)
    let mut g1 = g0.clone();
    for k in (0..100).chain(2000..2100) {
        g1[k] += 0.25;
    }

    let mut ckpt =
        CheckpointObserver::create(&store, &cfg("fedavg", 1), "fedavg", 1).unwrap();
    let id = ckpt.run_id().to_string();

    // first checkpoint: no base yet, so a full f32le blob
    ckpt.on_server_state(&ServerState {
        completed: 0,
        sim_time: 1.0,
        global: &g0,
        strategy: strategy.as_ref(),
        async_state: None,
    });
    assert!(ckpt.take_error().is_none());
    let full = store.load_manifest(&id).unwrap().checkpoint.unwrap();
    assert_eq!(full.params.media_type, MEDIA_PARAMS_F32LE);
    assert_eq!(full.params.size, 4 * n as u64);
    assert!(full.params_chain.is_empty());

    // second checkpoint: a sparse delta chained on the full base,
    // at least 5x smaller than a dense snapshot
    ckpt.on_server_state(&ServerState {
        completed: 0,
        sim_time: 2.0,
        global: &g1,
        strategy: strategy.as_ref(),
        async_state: None,
    });
    assert!(ckpt.take_error().is_none());
    let delta = store.load_manifest(&id).unwrap().checkpoint.unwrap();
    assert_eq!(delta.params.media_type, MEDIA_PARAMS_DELTA);
    assert_eq!(delta.params_chain, vec![full.params.clone()]);
    assert!(
        5 * delta.params.size <= full.params.size,
        "delta checkpoint should be >=5x smaller: {} vs {} bytes",
        delta.params.size,
        full.params.size
    );

    // the chained checkpoint resolves bitwise, through every read path
    for got in [
        store.resolve_params(&delta.params, &delta.params_chain).unwrap(),
        store.latest_params(&id).unwrap(),
        fedel::store::checkpoint::resume_state(
            &store,
            &store.load_manifest(&id).unwrap(),
        )
        .unwrap()
        .global,
    ] {
        assert_eq!(got.len(), n);
        for (a, b) in g1.iter().zip(&got) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    // a full-vector change beats any delta: the chain rebases
    let g2: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
    ckpt.on_server_state(&ServerState {
        completed: 0,
        sim_time: 3.0,
        global: &g2,
        strategy: strategy.as_ref(),
        async_state: None,
    });
    assert!(ckpt.take_error().is_none());
    let rebased = store.load_manifest(&id).unwrap().checkpoint.unwrap();
    assert_eq!(rebased.params.media_type, MEDIA_PARAMS_F32LE);
    assert!(rebased.params_chain.is_empty(), "full rewrite must rebase the chain");
    assert_eq!(store.latest_params(&id).unwrap(), g2);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_refuses_completed_or_checkpointless_runs() {
    let dir = scratch("refuse");
    let store = RunStore::open(&dir).unwrap();
    let mut exp = Experiment::build(cfg("fedavg", 1)).unwrap();
    let mut ckpt = CheckpointObserver::create(&store, &exp.cfg, "fedavg", 2).unwrap();
    let id = ckpt.run_id().to_string();

    // no checkpoint yet -> not resumable
    let err = resume_run(&store, &id, 2, &mut NullObserver).unwrap_err();
    assert!(err.to_string().contains("no checkpoint"), "{err}");

    // completed -> not resumable either
    exp.run_from(None, &mut ckpt, None).unwrap();
    let err = resume_run(&store, &id, 2, &mut NullObserver).unwrap_err();
    assert!(err.to_string().contains("completed"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// `--checkpoint-secs`: with a zero-second wall-clock cadence every round
/// persists a checkpoint, even when the round cadence alone would only
/// fire at the very end — and a kill between *round*-cadence points is
/// then still resumable from the latest round.
#[test]
fn wall_clock_cadence_checkpoints_between_round_cadence_points() {
    let dir = scratch("wallclock");
    let store = RunStore::open(&dir).unwrap();

    let mut halted = cfg("fedavg", 1);
    halted.halt_after = Some(5);
    let mut exp = Experiment::build(halted).unwrap();
    // round cadence alone would checkpoint only at round 1000...
    let mut ckpt = CheckpointObserver::create(&store, &exp.cfg, "fedavg", 1000)
        .unwrap()
        .every_secs(Some(0.0)); // ...but 0s of wall clock always elapsed
    let id = ckpt.run_id().to_string();
    let err = exp.run_from(None, &mut ckpt, None).unwrap_err();
    assert!(err.to_string().contains("halted"), "{err}");
    assert!(ckpt.take_error().is_none());

    let man = store.load_manifest(&id).unwrap();
    assert_eq!(man.checkpoint.as_ref().unwrap().completed, 5, "wall-clock cadence missed rounds");

    // and the wall-clock checkpoint is a real resume point
    let baseline = Experiment::build(cfg("fedavg", 1)).unwrap().run(None).unwrap();
    let resumed = resume_run(&store, &id, 2, &mut NullObserver).unwrap();
    assert_identical(&baseline, &resumed, "wall-clock resume");
    let _ = std::fs::remove_dir_all(&dir);
}
