//! Property-based invariants (in-repo prop harness, see util::prop):
//! randomized inputs for the DP selector, sliding window, aggregation,
//! masks, and the JSON substrate.

use fedel::elastic::{blend_importance, select, SelectorInput};
use fedel::fl::aggregate::{AggregateRule, MaskedAggregator};
use fedel::fl::bias::o1_bias;
use fedel::manifest::tests_support::chain_manifest;
use fedel::timing::{DeviceProfile, TimingCfg, TimingModel};
use fedel::util::json::Json;
use fedel::util::prop::{check, no_shrink, shrink_vec};
use fedel::util::rng::Rng;
use fedel::window::{initial_window, BlockCosts, WindowPolicy, WindowState};

#[test]
fn prop_selector_never_exceeds_budget() {
    let m = chain_manifest(12, 30);
    let tm = TimingModel::profile(&m, &DeviceProfile::orin(), &TimingCfg::default());
    let order: Vec<usize> = (0..12).rev().map(|b| 2 * b).collect();
    let full = tm.full_backward_time();
    check(
        "selector-budget",
        150,
        |r: &mut Rng| {
            let imp: Vec<f64> = (0..12).map(|_| r.f64() * 10.0).collect();
            let budget = r.f64() * full;
            (imp, budget)
        },
        |(imp, budget)| {
            let sel = select(&SelectorInput { order: &order, importance: imp, budget: *budget, timing: &tm });
            if sel.backward_time <= budget + 1e-9 {
                Ok(())
            } else {
                Err(format!("backward {} > budget {budget}", sel.backward_time))
            }
        },
        no_shrink,
    );
}

#[test]
fn prop_selector_selected_subset_of_order() {
    let m = chain_manifest(10, 20);
    let tm = TimingModel::profile(&m, &DeviceProfile::orin(), &TimingCfg::default());
    let full = tm.full_backward_time();
    check(
        "selector-subset",
        100,
        |r: &mut Rng| {
            // random contiguous window
            let end = r.below(9);
            let front = end + 1 + r.below(10 - end - 1).max(0);
            let front = front.min(10).max(end + 1);
            (end, front, r.f64() * full)
        },
        |&(end, front, budget)| {
            let order: Vec<usize> = (end..front).rev().map(|b| 2 * b).collect();
            let imp = vec![1.0; order.len()];
            let sel = select(&SelectorInput { order: &order, importance: &imp, budget, timing: &tm });
            for t in &sel.tensors {
                if !order.contains(t) {
                    return Err(format!("tensor {t} outside window [{end},{front})"));
                }
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn prop_selector_monotone_in_budget() {
    let m = chain_manifest(8, 25);
    let tm = TimingModel::profile(&m, &DeviceProfile::orin(), &TimingCfg::default());
    let order: Vec<usize> = (0..8).rev().map(|b| 2 * b).collect();
    let full = tm.full_backward_time();
    check(
        "selector-monotone",
        60,
        |r: &mut Rng| {
            let imp: Vec<f64> = (0..8).map(|_| 0.1 + r.f64()).collect();
            let b1 = r.f64() * full;
            (imp, b1)
        },
        |(imp, b1)| {
            let s1 = select(&SelectorInput { order: &order, importance: imp, budget: *b1, timing: &tm });
            let s2 = select(&SelectorInput { order: &order, importance: imp, budget: b1 * 2.0, timing: &tm });
            if s2.importance + 1e-9 >= s1.importance {
                Ok(())
            } else {
                Err(format!("importance dropped: {} -> {}", s1.importance, s2.importance))
            }
        },
        no_shrink,
    );
}

#[test]
fn prop_selector_near_optimal_vs_bruteforce() {
    // exhaustive check on small windows: the DP's captured importance must
    // be within bucket-quantization slack of the true optimum under the
    // exact Fig-3 cost model.
    let m = chain_manifest(8, 20);
    let tm = TimingModel::profile(&m, &DeviceProfile::orin(), &TimingCfg::default());
    let full = tm.full_backward_time();
    check(
        "selector-vs-bruteforce",
        40,
        |r: &mut Rng| {
            let n = 3 + r.below(5); // 3..=7 candidates
            let blocks: Vec<usize> = (0..n).collect();
            let order: Vec<usize> = blocks.iter().rev().map(|&b| 2 * b).collect();
            let imp: Vec<f64> = (0..n).map(|_| 0.1 + r.f64() * 5.0).collect();
            let budget = r.f64() * full * 0.8;
            (order, imp, budget)
        },
        |(order, imp, budget)| {
            let n = order.len();
            let sel = select(&SelectorInput {
                order,
                importance: imp,
                budget: *budget,
                timing: &tm,
            });
            // brute force: all subsets, exact cost via backward_time_for
            let mut best = 0.0f64;
            for bits in 0u32..(1 << n) {
                let picked: Vec<bool> = (0..n).map(|i| bits >> i & 1 == 1).collect();
                let cost = tm.backward_time_for(order, &picked);
                if cost <= *budget {
                    let v: f64 = (0..n).filter(|&i| picked[i]).map(|i| imp[i]).sum();
                    best = best.max(v);
                }
            }
            // allow quantization slack: one bucket of time can exclude one
            // tensor; bound the gap by the largest single importance.
            let max_imp = imp.iter().cloned().fold(0.0, f64::max);
            if sel.importance + max_imp + 1e-9 >= best {
                Ok(())
            } else {
                Err(format!("dp {} << brute {best}", sel.importance))
            }
        },
        no_shrink,
    );
}

#[test]
fn prop_window_always_valid() {
    check(
        "window-valid",
        200,
        |r: &mut Rng| {
            let nb = 2 + r.below(14);
            let costs: Vec<f64> = (0..nb).map(|_| 0.1 + r.f64() * 5.0).collect();
            let fwd: Vec<f64> = (0..nb).map(|_| r.f64()).collect();
            let t_th = 0.5 + r.f64() * 20.0;
            let policy = match r.below(3) {
                0 => WindowPolicy::FedEl,
                1 => WindowPolicy::Collapsed,
                _ => WindowPolicy::NoRollback,
            };
            let sels: Vec<u64> = (0..30).map(|_| r.next_u64()).collect();
            (costs, fwd, t_th, policy, sels)
        },
        |(costs, fwd, t_th, policy, sels)| {
            let nb = costs.len();
            let bc = BlockCosts::new(costs.clone(), fwd.clone());
            let mut st = WindowState::new(&bc, *t_th, *policy);
            for &bits in sels {
                if st.win.end >= st.win.front || st.win.front > nb {
                    return Err(format!("invalid window {:?}", st.win));
                }
                let block_sel: Vec<bool> = (0..nb).map(|b| bits >> (b % 64) & 1 == 1).collect();
                st.advance(&bc, *t_th, &block_sel);
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn prop_window_front_covers_model_over_time() {
    // under FedEl policy every block index is eventually inside a window
    check(
        "window-coverage",
        80,
        |r: &mut Rng| {
            let nb = 3 + r.below(10);
            let costs: Vec<f64> = (0..nb).map(|_| 0.5 + r.f64() * 2.0).collect();
            let t_th = 1.0 + r.f64() * 4.0;
            (costs, t_th)
        },
        |(costs, t_th)| {
            let nb = costs.len();
            let bc = BlockCosts::new(costs.clone(), vec![0.0; nb]);
            let mut st = WindowState::new(&bc, *t_th, WindowPolicy::FedEl);
            let mut seen = vec![false; nb];
            for _ in 0..10 * nb {
                for b in st.win.blocks() {
                    seen[b] = true;
                }
                st.advance(&bc, *t_th, &vec![true; nb]);
            }
            if seen.iter().all(|&s| s) {
                Ok(())
            } else {
                Err(format!("blocks never windowed: {seen:?}"))
            }
        },
        no_shrink,
    );
}

#[test]
fn prop_masked_aggregation_convex_hull() {
    // every aggregated element lies within [min, max] of contributions
    // (or equals the previous global when uncovered)
    check(
        "aggregation-hull",
        100,
        |r: &mut Rng| {
            let p = 1 + r.below(40);
            let n = 1 + r.below(6);
            let global: Vec<f32> = (0..p).map(|_| r.normal_f32()).collect();
            let clients: Vec<(Vec<f32>, Vec<f32>)> = (0..n)
                .map(|_| {
                    let params: Vec<f32> = (0..p).map(|_| r.normal_f32()).collect();
                    let mask: Vec<f32> = (0..p).map(|_| (r.below(2)) as f32).collect();
                    (params, mask)
                })
                .collect();
            (global, clients)
        },
        |(global, clients)| {
            let p = global.len();
            let mut agg = MaskedAggregator::new(p, AggregateRule::Masked);
            for (params, mask) in clients {
                agg.add(params, mask, 1.0, 1, global).unwrap();
            }
            let out = agg.finish(global);
            for k in 0..p {
                let contrib: Vec<f32> = clients
                    .iter()
                    .filter(|(_, m)| m[k] > 0.0)
                    .map(|(w, _)| w[k])
                    .collect();
                if contrib.is_empty() {
                    if out[k] != global[k] {
                        return Err(format!("uncovered elem {k} changed"));
                    }
                } else {
                    let lo = contrib.iter().cloned().fold(f32::MAX, f32::min) - 1e-4;
                    let hi = contrib.iter().cloned().fold(f32::MIN, f32::max) + 1e-4;
                    if out[k] < lo || out[k] > hi {
                        return Err(format!("elem {k}={} outside [{lo},{hi}]", out[k]));
                    }
                }
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn prop_sparse_aggregation_bitwise_equals_dense() {
    // The tentpole sparse-delta invariant: feeding an aggregator the
    // run-encoded masked update (add_sparse) must produce bitwise the
    // same global as feeding it the full dense vector (add), for every
    // rule — including FedNova's normalized-delta arithmetic — any mask
    // shape (runs of 0 / 0.5 / 1, occasionally all-zero), and any
    // weight/tau. Off-mask elements satisfy the engine contract: the
    // client returns them bitwise at the dispatched global.
    use fedel::fl::sparse::SparseDelta;
    check(
        "sparse-vs-dense-aggregation",
        150,
        |r: &mut Rng| {
            let p = 1 + r.below(60);
            let n = 1 + r.below(5);
            let rule = r.below(3);
            let global: Vec<f32> = (0..p).map(|_| r.normal_f32()).collect();
            let clients: Vec<(Vec<f32>, Vec<f32>, f64, usize)> = (0..n)
                .map(|_| {
                    let all_zero = r.below(8) == 0;
                    let mut mask = Vec::with_capacity(p);
                    while mask.len() < p {
                        let len = (1 + r.below(6)).min(p - mask.len());
                        let v = if all_zero {
                            0.0
                        } else {
                            [0.0f32, 0.5, 1.0][r.below(3)]
                        };
                        mask.extend(std::iter::repeat(v).take(len));
                    }
                    let params: Vec<f32> = (0..p)
                        .map(|k| if mask[k] > 0.0 { r.normal_f32() } else { global[k] })
                        .collect();
                    let weight = (1 + r.below(100)) as f64;
                    let tau = 1 + r.below(5);
                    (params, mask, weight, tau)
                })
                .collect();
            (rule, global, clients)
        },
        |(rule, global, clients)| {
            let rule = match *rule {
                0 => AggregateRule::Masked,
                1 => AggregateRule::FedAvg,
                _ => AggregateRule::FedNova,
            };
            let p = global.len();
            let mut dense = MaskedAggregator::new(p, rule);
            let mut sparse = MaskedAggregator::new(p, rule);
            for (params, mask, weight, tau) in clients {
                dense
                    .add(params, mask, *weight, *tau, global)
                    .map_err(|e| format!("dense add: {e}"))?;
                let delta = SparseDelta::from_dense_mask(mask, params);
                sparse
                    .add_sparse(&delta, *weight, *tau, global)
                    .map_err(|e| format!("sparse add: {e}"))?;
            }
            let a = dense.finish(global);
            let b = sparse.finish(global);
            for k in 0..p {
                if a[k].to_bits() != b[k].to_bits() {
                    return Err(format!(
                        "rule {rule:?} elem {k}: dense {} != sparse {}",
                        a[k], b[k]
                    ));
                }
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn prop_o1_nonnegative_and_zero_iff_uniform() {
    check(
        "o1-sign",
        100,
        |r: &mut Rng| {
            let k = 1 + r.below(12);
            let n = 1 + r.below(6);
            let masks: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..k).map(|_| r.below(2) as f32).collect())
                .collect();
            masks
        },
        |masks| {
            let v = o1_bias(masks);
            if v < -1e-9 {
                return Err(format!("negative bias {v}"));
            }
            Ok(())
        },
        shrink_vec,
    );
}

#[test]
fn prop_blend_is_normalized_convex() {
    check(
        "blend-convex",
        100,
        |r: &mut Rng| {
            let k = 1 + r.below(20);
            let l: Vec<f64> = (0..k).map(|_| r.f64() * 5.0).collect();
            let g: Vec<f64> = (0..k).map(|_| r.f64() * 5.0).collect();
            (l, g, r.f64())
        },
        |(l, g, beta)| {
            let b = blend_importance(l, g, *beta);
            let s: f64 = b.iter().sum();
            if (s - 1.0).abs() > 1e-6 {
                return Err(format!("sum {s} != 1"));
            }
            if b.iter().any(|&x| x < -1e-12) {
                return Err("negative blended importance".into());
            }
            Ok(())
        },
        no_shrink,
    );
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(r: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { r.below(4) } else { r.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(r.below(2) == 0),
            2 => Json::Num((r.normal() * 100.0 * 8.0).round() / 8.0),
            3 => Json::Str(format!("s{}", r.below(1000))),
            4 => Json::Arr((0..r.below(4)).map(|_| random_json(r, depth - 1)).collect()),
            _ => Json::Obj(
                (0..r.below(4))
                    .map(|i| (format!("k{i}"), random_json(r, depth - 1)))
                    .collect(),
            ),
        }
    }
    check(
        "json-roundtrip",
        200,
        |r: &mut Rng| random_json(r, 3),
        |j| {
            let text = j.to_string();
            let back = Json::parse(&text).map_err(|e| format!("{e}"))?;
            if &back == j {
                Ok(())
            } else {
                Err(format!("{j} -> {text} -> {back}"))
            }
        },
        no_shrink,
    );
}

#[test]
fn prop_initial_window_cost_just_exceeds_threshold() {
    check(
        "initial-window-tight",
        150,
        |r: &mut Rng| {
            let nb = 2 + r.below(12);
            let costs: Vec<f64> = (0..nb).map(|_| 0.1 + r.f64() * 3.0).collect();
            let total: f64 = costs.iter().sum();
            (costs, r.f64() * total * 1.2)
        },
        |(costs, t_th)| {
            let bc = BlockCosts::new(costs.clone(), vec![0.0; costs.len()]);
            let w = initial_window(&bc, *t_th);
            let sum: f64 = costs[..w.front].iter().sum();
            // either the window covers the whole model (t_th too big) or
            // its cost reached t_th and removing the last block would not
            if w.front < costs.len() {
                if sum < *t_th {
                    return Err(format!("window sum {sum} < t_th {t_th}"));
                }
                let prev: f64 = costs[..w.front - 1].iter().sum();
                if prev >= *t_th {
                    return Err(format!("window not minimal: prev {prev} >= {t_th}"));
                }
            }
            Ok(())
        },
        no_shrink,
    );
}
